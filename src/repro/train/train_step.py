"""Train/serve step builders with explicit shardings for AOT lowering.

``build_train_step(cfg, ocfg)`` returns a pure step fn + its in/out sharding
trees; the launcher jits with donation so params/opt-state/caches update in
place (crucial for the memory analysis to reflect reality).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models import transformer
from repro.models.common import ModelConfig, abstract_params, param_pspecs
from repro.sharding.partitioning import (
    batch_spec,
    cache_pspecs,
    dp_axes,
    named,
    named_sanitized,
)
from .optimizer import (
    OptConfig,
    abstract_opt_state,
    apply_adamw,
    init_opt_state,
    opt_state_pspecs,
)


# ----------------------------------------------------------------- train
def make_train_step(cfg: ModelConfig, ocfg: OptConfig):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: transformer.loss_fn(cfg, p, batch), has_aux=True
        )(params)
        params, opt_state, opt_metrics = apply_adamw(ocfg, params, grads, opt_state)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step


def train_step_shardings(cfg: ModelConfig, ocfg: OptConfig, mesh: Mesh, shape):
    """Returns (in_shardings, out_shardings) trees for jax.jit."""
    pp = param_pspecs(cfg)
    op = opt_state_pspecs(ocfg, pp)
    B = shape.global_batch
    batch_specs = {
        "tokens": batch_spec(mesh, B, 1),
        "labels": batch_spec(mesh, B, 1),
    }
    if cfg.num_encoder_tokens:
        batch_specs["encoder_states"] = batch_spec(mesh, B, 2)
    metrics_specs = {
        "loss": P(),
        "nll": P(),
        "aux": P(),
        "lr": P(),
        "grad_norm": P(),
    }
    ap = abstract_params(cfg)
    ao = abstract_opt_state(ocfg, ap)
    pshard = named_sanitized(mesh, pp, ap)
    oshard = named_sanitized(mesh, op, ao)
    ins = (pshard, oshard, named(mesh, batch_specs))
    outs = (pshard, oshard, named(mesh, metrics_specs))
    return ins, outs


def abstract_train_batch(cfg: ModelConfig, shape) -> dict:
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    batch = {
        "tokens": sds((B, S), jnp.int32),
        "labels": sds((B, S), jnp.int32),
    }
    if cfg.num_encoder_tokens:
        batch["encoder_states"] = sds(
            (B, cfg.num_encoder_tokens, cfg.d_model), cfg.dtype
        )
    return batch


# ----------------------------------------------------------------- prefill
def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        logits, cache = transformer.prefill(
            cfg, params, batch["tokens"], batch.get("encoder_states")
        )
        return logits, cache

    return prefill_step


def prefill_shardings(cfg: ModelConfig, mesh: Mesh, shape):
    pp = param_pspecs(cfg)
    B = shape.global_batch
    batch_specs = {"tokens": batch_spec(mesh, B, 1)}
    if cfg.num_encoder_tokens:
        batch_specs["encoder_states"] = batch_spec(mesh, B, 2)
    ins = (
        named_sanitized(mesh, pp, abstract_params(cfg)),
        named(mesh, batch_specs),
    )
    outs = (
        NamedSharding(mesh, batch_spec(mesh, B, 1)),  # logits (B, V)
        named_sanitized(
            mesh,
            cache_pspecs(cfg, mesh, B, mode="prefill"),
            transformer.abstract_cache(cfg, B, shape.seq_len),
        ),
    )
    return ins, outs


def abstract_prefill_batch(cfg: ModelConfig, shape) -> dict:
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    batch = {"tokens": sds((B, S), jnp.int32)}
    if cfg.num_encoder_tokens:
        batch["encoder_states"] = sds(
            (B, cfg.num_encoder_tokens, cfg.d_model), cfg.dtype
        )
    return batch


# ----------------------------------------------------------------- decode
def make_serve_step(cfg: ModelConfig):
    """One decode step: greedy-sample next token against the KV cache."""

    def serve_step(params, cache, token, position):
        logits, cache = transformer.decode_step(cfg, params, token, cache, position)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, cache

    return serve_step


def serve_shardings(cfg: ModelConfig, mesh: Mesh, shape):
    pp = param_pspecs(cfg)
    B = shape.global_batch
    acache = transformer.abstract_cache(cfg, B, shape.seq_len)
    cshard = named_sanitized(
        mesh, cache_pspecs(cfg, mesh, B, mode="decode"), acache
    )
    tok_spec = batch_spec(mesh, B, 0)
    ins = (
        named_sanitized(mesh, pp, abstract_params(cfg)),
        cshard,
        NamedSharding(mesh, tok_spec),
        NamedSharding(mesh, tok_spec),
    )
    outs = (NamedSharding(mesh, tok_spec), cshard)
    return ins, outs


def abstract_serve_inputs(cfg: ModelConfig, shape):
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    cache = transformer.abstract_cache(cfg, B, S)
    return cache, sds((B,), jnp.int32), sds((B,), jnp.int32)
