import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimbing driver (EXPERIMENTS.md §Perf).

Measures named config VARIANTS of the three chosen cells and logs
hypothesis -> change -> before/after on the dominant roofline term.

  PYTHONPATH=src python -m repro.launch.hillclimb --cell musicgen_prefill
"""
import argparse
import dataclasses
import json

from repro.configs import SHAPES, get_config
from repro.launch import roofline as rl

# Registry of (arch, shape, [(variant_name, config_transform), ...])
def _v(name, **kw):
    return (name, lambda cfg: dataclasses.replace(cfg, **kw))


CELLS = {
    "musicgen_prefill": {
        "arch": "musicgen-large",
        "shape": "prefill_32k",
        "variants": [
            ("baseline", lambda cfg: cfg),
            _v("bf16_scores", attn_bf16_scores=True),
            _v("seq_parallel", seq_parallel=True),
            _v("seq_parallel+bf16", seq_parallel=True, attn_bf16_scores=True),
        ],
    },
    "jamba_decode": {
        "arch": "jamba-1.5-large-398b",
        "shape": "decode_32k",
        "variants": [
            ("baseline", lambda cfg: cfg),
            _v("ep_experts", moe_ep=True),
            _v("ep+tp_resident", moe_ep=True, fsdp_params=False),
        ],
    },
    "llama_decode": {
        "arch": "llama-3.2-vision-90b",
        "shape": "decode_32k",
        "variants": [
            ("baseline", lambda cfg: cfg),
            _v("tp_resident", fsdp_params=False),
            _v("tp_resident+int8kv", fsdp_params=False, kv_quant=True),
            _v("int8kv_only", kv_quant=True),
        ],
    },
}


def measure(arch, shape_name, cfg, multi_pod=False):
    """corrected_record but with an explicit (possibly variant) config."""
    import repro.configs.registry as registry

    # Temporarily override the registry so lower_cell/body_costs see the variant
    orig = registry.get_config
    registry.get_config = lambda a: cfg if a == arch else orig(a)
    import repro.launch.dryrun as dr

    orig_dr = dr  # lower_cell uses repro.configs get_config import
    import repro.configs as configs_pkg

    orig_pkg = configs_pkg.get_config
    configs_pkg.get_config = registry.get_config
    rl.get_config = registry.get_config
    dr.get_config = registry.get_config
    try:
        rec = rl.corrected_record(arch, shape_name, multi_pod,
                                  dryrun_results="/nonexistent")
    finally:
        registry.get_config = orig
        configs_pkg.get_config = orig_pkg
        rl.get_config = orig
        dr.get_config = orig
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=list(CELLS))
    ap.add_argument("--variant", default=None)
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()

    spec = CELLS[args.cell]
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, f"{args.cell}.jsonl")
    for name, tf in spec["variants"]:
        if args.variant and name != args.variant:
            continue
        cfg = tf(get_config(spec["arch"]))
        rec = measure(spec["arch"], spec["shape"], cfg)
        rec["variant"] = name
        rec["cell"] = args.cell
        print(
            f"{args.cell:18s} {name:22s} C={rec['compute_s']:.4f} "
            f"M={rec['memory_s']:.4f} X={rec['collective_s']:.4f} "
            f"-> {rec['bottleneck']} step={rec['step_time_s']:.4f}s",
            flush=True,
        )
        with open(path, "a") as f:
            f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
