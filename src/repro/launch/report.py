"""Generate EXPERIMENTS.md tables from experiments/*.jsonl."""
from __future__ import annotations

import json
import os


def _load(path):
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return [json.loads(l) for l in f]


def dryrun_table(path="experiments/dryrun/results.jsonl") -> str:
    rows = _load(path)
    out = [
        "| arch | shape | mesh | bytes/dev (args) | temp/dev | HLO flops/dev | collective B/dev | compile |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        ma = r["memory_analysis"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {ma['argument_bytes']/1e9:.2f} GB | {ma['temp_bytes']/1e9:.1f} GB "
            f"| {r['flops_per_device']:.2e} | {r['collective_bytes_per_device']['total']:.2e} "
            f"| {r['compile_s']:.0f}s |"
        )
    return "\n".join(out)


def roofline_table(path="experiments/roofline/roofline.jsonl") -> str:
    rows = _load(path)
    out = [
        "| arch | shape | compute s | memory s | collective s | bottleneck | 6ND/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"| {r['collective_s']:.4f} | {r['bottleneck']} "
            f"| {r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.3f} |"
        )
    return "\n".join(out)


def perf_tables(dirpath="experiments/perf") -> str:
    out = []
    if not os.path.isdir(dirpath):
        return ""
    for fn in sorted(os.listdir(dirpath)):
        if not fn.endswith(".jsonl"):
            continue
        rows = _load(os.path.join(dirpath, fn))
        out.append(f"\n### {fn[:-6]}\n")
        out.append("| variant | compute s | memory s | collective s | bottleneck | step s | vs baseline |")
        out.append("|---|---|---|---|---|---|---|")
        base = None
        for r in rows:
            if base is None:
                base = r["step_time_s"]
            out.append(
                f"| {r['variant']} | {r['compute_s']:.4f} | {r['memory_s']:.4f} "
                f"| {r['collective_s']:.4f} | {r['bottleneck']} | {r['step_time_s']:.4f} "
                f"| {base/r['step_time_s']:.2f}x |"
            )
    return "\n".join(out)


if __name__ == "__main__":
    import sys

    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("## Dry-run\n")
        print(dryrun_table())
    if which in ("all", "roofline"):
        print("\n## Roofline\n")
        print(roofline_table())
    if which in ("all", "perf"):
        print("\n## Perf\n")
        print(perf_tables())
