import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST be the first two lines: jax locks the device count on first init.
# Everything below may import jax.
import argparse
import json
import re
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, applicable_shapes, get_config
from repro.launch.mesh import make_production_mesh
from repro.sharding.context import use_mesh
from repro.train.optimizer import OptConfig
from repro.train import train_step as ts

# ---------------------------------------------------------------- constants
PEAK_FLOPS = 197e12  # TPU v5e bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per ICI link

_COLL_RE = re.compile(
    r"=\s*\(?(\w+)\[([\d,]*)\][^ ]*\s+"
    r"(all-reduce-start|all-gather-start|reduce-scatter|all-to-all|"
    r"collective-permute-start|all-reduce|all-gather|collective-permute)\(",
)
_GROUPS_RE = re.compile(
    r"replica_groups=(\{\{[^}]*\}[^}]*\}|\[[\d,]+\]<=\[[^\]]*\](?:T\([^)]*\))?)"
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if not m:
        return default
    g = m.group(1)
    if g.startswith("{{"):
        first = g[2:].split("}", 1)[0]
        return len(first.split(","))
    # iota form: [n_groups,group_size]<=[dims...](T(perm))?
    dims = g[1:].split("]", 1)[0].split(",")
    return int(dims[-1])  # group_size is the trailing dim


def collective_bytes_per_device(hlo_text: str, default_group: int) -> dict:
    """Parse per-device link bytes from the compiled HLO, with ring-algorithm
    factors per op kind. Returns {op_kind: bytes, 'total': bytes}."""
    out: dict[str, float] = {}
    total = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        dtype, shape_s, kind = m.group(1), m.group(2), m.group(3)
        kind = kind.replace("-start", "")
        nbytes = _DTYPE_BYTES.get(dtype, 4)
        for d in shape_s.split(","):
            if d:
                nbytes *= int(d)
        g = _group_size(line, default_group)
        if g <= 1:
            continue
        if kind == "all-gather":
            moved = nbytes * (g - 1) / g  # result is the gathered buffer
        elif kind == "all-reduce":
            moved = nbytes * 2 * (g - 1) / g
        elif kind == "reduce-scatter":
            moved = nbytes * (g - 1)  # result is the scattered shard
        elif kind == "all-to-all":
            moved = nbytes * (g - 1) / g
        else:  # collective-permute
            moved = nbytes
        out[kind] = out.get(kind, 0.0) + moved
        total += moved
    out["total"] = total
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool):
    """Build + lower + compile one (arch, shape, mesh) cell. Returns record."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    ocfg = OptConfig(
        moment_dtype=cfg.optim_moment_dtype, master_fp32=cfg.optim_master_fp32
    )

    from repro.models.common import abstract_params, count_active_params

    aparams = abstract_params(cfg)

    with mesh, use_mesh(mesh):
        if shape.kind == "train":
            step = ts.make_train_step(cfg, ocfg)
            ins, outs = ts.train_step_shardings(cfg, ocfg, mesh, shape)
            from repro.train.optimizer import abstract_opt_state

            args = (aparams, abstract_opt_state(ocfg, aparams),
                    ts.abstract_train_batch(cfg, shape))
            jitted = jax.jit(step, in_shardings=ins, out_shardings=outs,
                             donate_argnums=(0, 1))
        elif shape.kind == "prefill":
            step = ts.make_prefill_step(cfg)
            ins, outs = ts.prefill_shardings(cfg, mesh, shape)
            args = (aparams, ts.abstract_prefill_batch(cfg, shape))
            jitted = jax.jit(step, in_shardings=ins, out_shardings=outs)
        else:  # decode
            step = ts.make_serve_step(cfg)
            ins, outs = ts.serve_shardings(cfg, mesh, shape)
            cache, tok, pos = ts.abstract_serve_inputs(cfg, shape)
            args = (aparams, cache, tok, pos)
            jitted = jax.jit(step, in_shardings=ins, out_shardings=outs,
                             donate_argnums=(1,))

        t0 = time.time()
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes_per_device(hlo, default_group=chips)

    flops_per_dev = float(cost.get("flops", 0.0))
    bytes_per_dev = float(cost.get("bytes accessed", 0.0))

    # tokens processed by the step (for MODEL_FLOPS = 6*N_active*D)
    if shape.kind == "decode":
        tokens = shape.global_batch
    else:
        tokens = shape.global_batch * shape.seq_len
    n_active = count_active_params(cfg)
    model_flops = 6 * n_active * tokens if shape.kind == "train" else 2 * n_active * tokens

    compute_s = flops_per_dev / PEAK_FLOPS
    memory_s = bytes_per_dev / HBM_BW
    collective_s = coll["total"] / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)

    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "kind": shape.kind,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops_per_device": flops_per_dev,
        "bytes_per_device": bytes_per_dev,
        "collective_bytes_per_device": coll,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": bottleneck,
        "model_flops_global": model_flops,
        "useful_flops_ratio": (
            model_flops / (flops_per_dev * chips) if flops_per_dev else 0.0
        ),
        "memory_analysis": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
    }
    return record, mem, cost


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: applicable)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = [args.arch] if args.arch else list(ARCH_IDS)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results_path = os.path.join(args.out, "results.jsonl")
    done = set()
    if args.skip_existing and os.path.exists(results_path):
        with open(results_path) as f:
            for line in f:
                r = json.loads(line)
                done.add((r["arch"], r["shape"], r["mesh"]))

    n_ok = n_fail = 0
    for arch in archs:
        cfg = get_config(arch)
        shapes = [args.shape] if args.shape else applicable_shapes(cfg)
        for shape_name in shapes:
            for multi_pod in meshes:
                mesh_name = "2x16x16" if multi_pod else "16x16"
                if (arch, shape_name, mesh_name) in done:
                    continue
                tag = f"{arch} x {shape_name} x {mesh_name}"
                print(f"=== {tag} ===", flush=True)
                try:
                    record, mem, cost = lower_cell(arch, shape_name, multi_pod)
                    print(f"memory_analysis: {mem}", flush=True)
                    print(
                        "cost_analysis: flops={:.3e} bytes={:.3e}".format(
                            record["flops_per_device"], record["bytes_per_device"]
                        ),
                        flush=True,
                    )
                    print(
                        "roofline: compute={compute_s:.4f}s memory={memory_s:.4f}s "
                        "collective={collective_s:.4f}s bottleneck={bottleneck} "
                        "useful={useful_flops_ratio:.2f}".format(**record),
                        flush=True,
                    )
                    with open(results_path, "a") as f:
                        f.write(json.dumps(record) + "\n")
                    n_ok += 1
                except Exception:
                    traceback.print_exc()
                    with open(os.path.join(args.out, "failures.log"), "a") as f:
                        f.write(f"{tag}\n{traceback.format_exc()}\n")
                    n_fail += 1
    print(f"dry-run complete: {n_ok} ok, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
