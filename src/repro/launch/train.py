"""End-to-end training driver.

CPU (smoke/dev):    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke --steps 20
Production shape:   same flags minus --smoke, plus --mesh single|multi (AOT
                    compiles the full config on the production mesh).

Features: ordered data pipeline with exactly-once resume, checkpoint/restart
(atomic, elastic-reshardable), optional int8 error-feedback gradient
compression across pods, straggler-tolerant by construction (pure SPMD step).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models.common import count_params, init_params
from repro.train.checkpoint import CheckpointManager
from repro.train.data import DataConfig, OrderedTokenPipeline
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    ocfg = OptConfig(
        peak_lr=args.lr,
        warmup_steps=max(args.steps // 10, 2),
        decay_steps=args.steps,
        moment_dtype=cfg.optim_moment_dtype,
        master_fp32=cfg.optim_master_fp32,
    )
    print(f"arch={cfg.name} params={count_params(cfg)/1e6:.1f}M")

    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_state = init_opt_state(ocfg, params)
    data = OrderedTokenPipeline(
        DataConfig(cfg.vocab_size, args.seq, args.batch, seed=args.seed)
    )
    start_step = 0

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt and args.resume and ckpt.latest_step() is not None:
        start_step, state, extra = ckpt.restore()
        params, opt_state = state["params"], state["opt"]
        data.seek(extra["data_serial"])  # exactly-once resume
        print(f"resumed from step {start_step} (data serial {data.cursor()})")

    step_fn = jax.jit(make_train_step(cfg, ocfg), donate_argnums=(0, 1))

    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = next(data)
        jbatch = {
            "tokens": jnp.asarray(batch["tokens"]),
            "labels": jnp.asarray(batch["labels"]),
        }
        if cfg.num_encoder_tokens:
            jbatch["encoder_states"] = jnp.zeros(
                (args.batch, cfg.num_encoder_tokens, cfg.d_model), cfg.dtype
            )
        params, opt_state, metrics = step_fn(params, opt_state, jbatch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(
                f"step {step:5d} loss={losses[-1]:.4f} "
                f"lr={float(metrics['lr']):.2e} gnorm={float(metrics['grad_norm']):.3f} "
                f"({(time.time()-t0)/(step-start_step+1):.2f}s/step)"
            )
        if ckpt and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            ckpt.save(
                step + 1,
                {"params": params, "opt": opt_state},
                extra={"data_serial": data.cursor()},
            )
    if ckpt and args.ckpt_every:
        ckpt.save(
            args.steps,
            {"params": params, "opt": opt_state},
            extra={"data_serial": data.cursor()},
        )
    if len(losses) >= 16 and losses[-1] >= losses[0]:
        print("WARNING: loss did not decrease over the run")
    print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
