"""Ordered serving driver.

  PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --requests 12
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from repro.configs import smoke_config
from repro.models.common import init_params
from repro.serve.engine import OrderedServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--schedule", default="interleave",
                    choices=["interleave", "prefill_first"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    eng = OrderedServingEngine(
        cfg, params, max_slots=args.slots, max_len=args.max_len,
        schedule=args.schedule,
    )
    rng = np.random.RandomState(args.seed)
    serials = []
    for _ in range(args.requests):
        prompt = rng.randint(0, cfg.vocab_size, size=rng.randint(4, 20))
        serials.append(eng.submit(prompt, max_new_tokens=int(rng.randint(4, 16))))
    t0 = time.perf_counter()
    comps = eng.run_to_completion()
    wall = time.perf_counter() - t0
    assert [c.serial for c in comps] == sorted(serials)
    total_tokens = sum(len(c.tokens) for c in comps)
    print(
        f"arch={cfg.name} schedule={args.schedule}: {len(comps)} requests, "
        f"{total_tokens} tokens in {wall:.2f}s "
        f"({total_tokens/wall:.1f} tok/s); ordered egress verified; "
        f"stats={eng.stats}"
    )
    return comps


if __name__ == "__main__":
    main()
