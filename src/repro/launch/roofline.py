import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Loop-corrected roofline analysis (EXPERIMENTS.md §Roofline).

XLA's cost analysis counts a while-loop body ONCE regardless of trip count
(verified: scan-of-8-matmuls reports 1/8 the flops of the unrolled version).
Our models scan over `num_periods`, so aggregate program costs undercount by
~nP. Correction: compile each period body STANDALONE with identical shardings
and add (nP - 1) x its costs to the aggregate:

  train   : total = agg + (nP-1) * (fwd_body + grad_body)
            (full-remat bwd scan body = refwd + bwd = grad_body exactly)
  prefill : total = agg + (nP-1) * prefill_body
  decode  : total = agg + (nP-1) * decode_body

Collective bytes get the same correction (bodies parsed separately).
"""
import argparse
import json

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, applicable_shapes, get_config
from repro.launch.dryrun import (
    HBM_BW,
    LINK_BW,
    PEAK_FLOPS,
    collective_bytes_per_device,
    lower_cell,
)
from repro.launch.mesh import make_production_mesh
from repro.models import transformer
from repro.models.common import (
    ModelConfig,
    abstract_period_params,
    count_active_params,
    period_pspecs,
)
from repro.sharding.context import use_mesh
from repro.sharding.partitioning import (
    batch_spec,
    cache_slice_pspecs,
    named,
    named_sanitized,
)


def _costs_of(compiled, chips: int) -> dict:
    cost = compiled.cost_analysis()
    coll = collective_bytes_per_device(compiled.as_text(), default_group=chips)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll["total"],
    }


def body_costs(cfg: ModelConfig, shape, mesh) -> dict:
    """Compile the period body standalone; returns per-device costs."""
    chips = mesh.size
    B, S = shape.global_batch, shape.seq_len
    app = abstract_period_params(cfg)
    pspec = period_pspecs(cfg)
    sds = jax.ShapeDtypeStruct
    x = sds((B, 1 if shape.kind == "decode" else S, cfg.d_model), cfg.dtype)
    xspec = batch_spec(mesh, B, 2)
    enc = (
        sds((B, cfg.num_encoder_tokens, cfg.d_model), cfg.dtype)
        if cfg.num_encoder_tokens
        else None
    )
    espec = batch_spec(mesh, B, 2)

    with mesh, use_mesh(mesh):
        if shape.kind == "train":
            def fwd(xx, lp, ee=None):
                h, aux = transformer.apply_period_train(cfg, xx, lp, ee)
                return h, aux

            def lossy(xx, lp, ee=None):
                h, aux = transformer.apply_period_train(cfg, xx, lp, ee)
                return h.astype(jnp.float32).sum() + aux

            grad_fn = jax.grad(lossy, argnums=(0, 1))
            args = (x, app) + ((enc,) if enc is not None else ())
            ins = (NamedSharding(mesh, xspec), named_sanitized(mesh, pspec, app)) + (
                (NamedSharding(mesh, espec),) if enc is not None else ()
            )
            cf = jax.jit(fwd, in_shardings=ins).lower(*args).compile()
            cg = jax.jit(grad_fn, in_shardings=ins).lower(*args).compile()
            f, g = _costs_of(cf, chips), _costs_of(cg, chips)
            return {k: f[k] + g[k] for k in f}

        if shape.kind == "prefill":
            def pf(xx, lp, ee=None):
                return transformer.apply_period_prefill(cfg, xx, lp, ee, max_len=S)

            args = (x, app) + ((enc,) if enc is not None else ())
            ins = (NamedSharding(mesh, xspec), named_sanitized(mesh, pspec, app)) + (
                (NamedSharding(mesh, espec),) if enc is not None else ()
            )
            cp = jax.jit(pf, in_shardings=ins).lower(*args).compile()
            return _costs_of(cp, chips)

        # decode
        cache_slice = transformer.abstract_cache_slice(cfg, B, S)
        cspec = cache_slice_pspecs(cfg, mesh, B, mode="decode")
        pos = jax.ShapeDtypeStruct((B,), jnp.int32)

        def dec(xx, lp, cs, pp):
            return transformer.apply_period_decode(cfg, xx, lp, cs, pp)

        ins = (
            NamedSharding(mesh, xspec),
            named_sanitized(mesh, pspec, app),
            named_sanitized(mesh, cspec, cache_slice),
            NamedSharding(mesh, batch_spec(mesh, B, 0)),
        )
        cd = (
            jax.jit(dec, in_shardings=ins, donate_argnums=(2,))
            .lower(x, app, cache_slice, pos)
            .compile()
        )
        return _costs_of(cd, chips)


_DRYRUN_CACHE: dict = {}


def _load_dryrun(path: str) -> dict:
    if path not in _DRYRUN_CACHE:
        recs = {}
        if os.path.exists(path):
            with open(path) as f:
                for line in f:
                    r = json.loads(line)
                    recs[(r["arch"], r["shape"], r["mesh"])] = r
        _DRYRUN_CACHE[path] = recs
    return _DRYRUN_CACHE[path]


def corrected_record(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    dryrun_results: str = "experiments/dryrun/results.jsonl",
) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size

    mesh_name = "2x16x16" if multi_pod else "16x16"
    record = _load_dryrun(dryrun_results).get((arch, shape_name, mesh_name))
    if record is None:  # fall back to a fresh full-program compile
        record, _mem, _cost = lower_cell(arch, shape_name, multi_pod)
    body = body_costs(cfg, shape, mesh)
    nP = cfg.num_periods

    flops = record["flops_per_device"] + (nP - 1) * body["flops"]
    bytes_ = record["bytes_per_device"] + (nP - 1) * body["bytes"]
    coll = record["collective_bytes_per_device"]["total"] + (nP - 1) * body["coll"]

    tokens = (
        shape.global_batch
        if shape.kind == "decode"
        else shape.global_batch * shape.seq_len
    )
    n_active = count_active_params(cfg)
    mult = 6 if shape.kind == "train" else 2
    model_flops = mult * n_active * tokens

    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_ / HBM_BW,
        "collective_s": coll / LINK_BW,
    }
    bottleneck = max(terms, key=terms.get).replace("_s", "")
    step_s = max(terms.values())
    achieved = model_flops / chips / step_s if step_s > 0 else 0.0

    return dict(
        record,
        corrected=True,
        body_flops=body["flops"],
        body_bytes=body["bytes"],
        body_coll=body["coll"],
        flops_per_device=flops,
        bytes_per_device=bytes_,
        collective_total_bytes=coll,
        **terms,
        bottleneck=bottleneck,
        model_flops_global=model_flops,
        useful_flops_ratio=model_flops / (flops * chips) if flops else 0.0,
        roofline_fraction=achieved / PEAK_FLOPS,
        step_time_s=step_s,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/roofline")
    ap.add_argument(
        "--dryrun-results", default="experiments/dryrun/results.jsonl",
        help="reuse full-program aggregates from a dry-run results file",
    )
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, "roofline.jsonl")
    done = set()
    if args.skip_existing and os.path.exists(path):
        with open(path) as f:
            done = {
                (r["arch"], r["shape"], r["mesh"])
                for r in map(json.loads, f)
            }

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    for arch in archs:
        cfg = get_config(arch)
        shapes = [args.shape] if args.shape else applicable_shapes(cfg)
        for shape_name in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                if (arch, shape_name, mesh_name) in done:
                    continue
                try:
                    rec = corrected_record(
                        arch, shape_name, mp, dryrun_results=args.dryrun_results
                    )
                    print(
                        f"{arch:26s} {shape_name:12s} {mesh_name:8s} "
                        f"C={rec['compute_s']:.4f}s M={rec['memory_s']:.4f}s "
                        f"X={rec['collective_s']:.4f}s -> {rec['bottleneck']:10s} "
                        f"useful={rec['useful_flops_ratio']:.2f} "
                        f"roofline={rec['roofline_fraction']:.3f}",
                        flush=True,
                    )
                    with open(path, "a") as f:
                        f.write(json.dumps(rec) + "\n")
                except Exception:
                    import traceback

                    traceback.print_exc()


if __name__ == "__main__":
    main()
