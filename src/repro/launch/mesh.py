"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state. The dry-run forces 512 host devices via
XLA_FLAGS *before* any jax import (see dryrun.py); smoke tests and benches see
the real single CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(*, multi_pod: bool = False):
    """Reduced mesh for CI on a handful of forced host devices (8)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
