"""mamba2-780m [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060]. d_inner = 2*d_model = 3072, head_dim 64 -> 48 SSD heads,
state N=128."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    pattern=(("mamba", "none"),),
    norm_type="rmsnorm",
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
)
