"""chatglm3-6b [dense] — 2d-RoPE (rotate half of head_dim), GQA kv=2
[arXiv:2406.12793]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    pattern=(("attn", "mlp"),),
    norm_type="rmsnorm",
    ffn_act="swiglu",
    rope_theta=1e4,
    rope_fraction=0.5,  # GLM's 2d rope: only half the head dims rotate
)
