"""olmo-1b [dense] — non-parametric LN [arXiv:2402.00838; hf]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    pattern=(("attn", "mlp"),),
    norm_type="nonparametric_ln",
    ffn_act="swiglu",
    rope_theta=1e4,
)
