"""Architecture registry: full configs (dry-run) + reduced smoke configs (CPU).

``get_config(arch_id)`` returns the exact assigned configuration;
``smoke_config(arch_id)`` returns a structurally identical reduced config
(same family, pattern, norm/rope/MoE topology) small enough for a CPU
forward/train step.
"""
from __future__ import annotations

import dataclasses
import importlib

_MODULES = {
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "musicgen-large": "musicgen_large",
    "mamba2-780m": "mamba2_780m",
    "olmo-1b": "olmo_1b",
    "glm4-9b": "glm4_9b",
    "starcoder2-15b": "starcoder2_15b",
    "chatglm3-6b": "chatglm3_6b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def smoke_config(arch_id: str, num_periods: int = 2):
    """Reduced config of the same family: small dims, few experts, tiny vocab."""
    cfg = get_config(arch_id)
    period = len(cfg.pattern)
    heads = 4 if cfg.num_heads else 0
    kv = min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0
    changes = dict(
        name=cfg.name + "-smoke",
        num_layers=period * num_periods,
        d_model=64,
        num_heads=heads,
        num_kv_heads=kv or heads if heads else 0,
        head_dim=16 if heads else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        num_encoder_tokens=16 if cfg.num_encoder_tokens else 0,
        ssm_state=16,
        ssm_head_dim=16,
        ssm_chunk=8,
        remat="none",
    )
    if cfg.num_experts:
        changes.update(
            num_experts=min(cfg.num_experts, 8),
            top_k=min(cfg.top_k, 2),
            moe_d_ff=64,
            num_shared_experts=min(cfg.num_shared_experts, 2),
        )
    return dataclasses.replace(cfg, **changes)
