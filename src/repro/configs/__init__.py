from .registry import ARCH_IDS, get_config, smoke_config
from .shapes import SHAPES, ShapeSpec, applicable_shapes

__all__ = [
    "ARCH_IDS",
    "get_config",
    "smoke_config",
    "SHAPES",
    "ShapeSpec",
    "applicable_shapes",
]
