"""qwen2-moe-a2.7b [moe] — 60 routed experts top-4 + 4 shared experts,
expert d_ff=1408 [hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    pattern=(("attn", "moe"),),
    norm_type="rmsnorm",
    ffn_act="swiglu",
    num_experts=60,
    top_k=4,
    moe_d_ff=1408,
    num_shared_experts=4,
    rope_theta=1e6,
)
