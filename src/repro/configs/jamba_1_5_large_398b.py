"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e
top-2 every other layer [arXiv:2403.19887]. Period of 8 layers: attention at
slot 4, MoE on odd slots; 72 layers = 9 periods. 398B total / ~94B active.
Optimizer states bf16 + no fp32 master so the model fits 256 chips.
"""
import jax.numpy as jnp

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    pattern=(
        ("mamba", "mlp"),
        ("mamba", "moe"),
        ("mamba", "mlp"),
        ("mamba", "moe"),
        ("attn", "mlp"),
        ("mamba", "moe"),
        ("mamba", "mlp"),
        ("mamba", "moe"),
    ),
    norm_type="rmsnorm",
    ffn_act="swiglu",
    num_experts=16,
    top_k=2,
    moe_d_ff=24576,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    rope_theta=1e6,
    optim_moment_dtype=jnp.bfloat16,
    optim_master_fp32=False,
)
