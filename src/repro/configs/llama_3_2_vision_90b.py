"""llama-3.2-vision-90b [vlm] — 100 layers: cross-attn image layer every 5th
(80 self + 20 cross), GQA kv=8 [hf:meta-llama/Llama-3.2-90B-Vision].
Vision frontend is a STUB: ``input_specs`` provides precomputed patch
embeddings (B, 576, d_model) consumed by the cross-attention layers.
"""
import jax.numpy as jnp

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    pattern=(
        ("attn", "mlp"),
        ("attn", "mlp"),
        ("attn", "mlp"),
        ("attn", "mlp"),
        ("xattn", "mlp"),
    ),
    norm_type="rmsnorm",
    ffn_act="swiglu",
    rope_theta=5e5,
    num_encoder_tokens=576,
    optim_moment_dtype=jnp.bfloat16,  # 90B: keep optimizer state lean
)
