"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2, expert d_ff=6400
[hf:microsoft/Phi-3.5-MoE-instruct]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    pattern=(("attn", "moe"),),
    norm_type="layernorm",
    ffn_act="swiglu",
    num_experts=16,
    top_k=2,
    moe_d_ff=6400,
    rope_theta=1e4,
)
