"""musicgen-large [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284]. Backbone only: the EnCodec frontend is a stub; inputs are
code tokens (vocab 2048). LayerNorm + GELU per the original transformer LM;
positional encoding adapted to RoPE (framework-native; noted in DESIGN.md).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    pattern=(("attn", "mlp"),),
    norm_type="layernorm",
    ffn_act="gelu",
    rope_theta=1e4,
)
