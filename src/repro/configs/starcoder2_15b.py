"""starcoder2-15b [dense] — GQA kv=4, RoPE, LayerNorm+GELU [arXiv:2402.19173]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    pattern=(("attn", "mlp"),),
    norm_type="layernorm",
    ffn_act="gelu",
    rope_theta=1e5,
)
