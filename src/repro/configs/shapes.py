"""Assigned input shapes (arch-family: LM transformers).

``decode_*`` / ``long_*`` lower ``serve_step`` (one token against a KV cache of
seq_len); ``train_4k`` lowers ``train_step``; ``prefill_32k`` lowers the
prefill step. ``long_500k`` requires a sub-quadratic path: only SSM/hybrid
archs run it (see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# archs whose pattern contains no full-attention-free path must skip long_500k
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def applicable_shapes(cfg) -> list[str]:
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.family in SUBQUADRATIC_FAMILIES:
        names.append("long_500k")
    return names
