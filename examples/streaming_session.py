"""Streaming Session: push traffic in, pull ordered results out — no finite
source required (the ROADMAP's serving-grade surface).

Opens the same keyed pipeline as a live session on BOTH backends: the thread
runtime processes pushes concurrently; the process backend feeds the stage-0
shared-memory exchange incrementally while forked worker groups execute the
stages.  Ordered egress is identical either way.

  PYTHONPATH=src python examples/streaming_session.py
"""
from repro.core import Engine, EngineConfig, OpSpec


def build_specs():
    return [
        OpSpec("square", "stateless", _square, cost_us=2),
        OpSpec(
            "running_sum", "partitioned", _running_sum,
            key_fn=_mod7, num_partitions=14, init_state=_zero, cost_us=4,
        ),
    ]


def _square(v):
    return [v * v]


def _running_sum(s, k, v):
    s += v
    return s, [(k, s)]


def _mod7(v):
    return v % 7


def _zero():
    return 0


def reference(n):
    state = {}
    out = []
    for v in range(n):
        vv = v * v
        k = vv % 7
        state[k] = state.get(k, 0) + vv
        out.append((k, state[k]))
    return out


def main():
    n = 2000
    expected = reference(n)
    for backend in ("thread", "process"):
        engine = Engine(EngineConfig(backend=backend, num_workers=2))
        plan = engine.plan(build_specs())
        with engine.open(plan) as session:
            # interleave pushes with ordered reads, like a serving loop
            session.push(range(0, n // 2))
            head = list(session.results(max_items=100))
            session.push(range(n // 2, n))
            print(f"{backend}: mid-stream stats {session.stats()}")
            report = session.close()
            tail = list(session.results())
        got = head + tail
        assert got == expected, f"{backend}: ordering violated"
        print(f"{backend}: {report} — ordered egress verified ({len(got)} tuples)")


if __name__ == "__main__":
    main()
