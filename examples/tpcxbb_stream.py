"""Run the TPCx-BB streaming queries (paper §7) on the threaded runtime.

  PYTHONPATH=src python examples/tpcxbb_stream.py [q1|q2|q3|q4|q15] [n_tuples]
"""
import sys

from repro.core import run_pipeline
from repro.streams.tpcxbb import QUERIES


def main():
    qname = sys.argv[1] if len(sys.argv) > 1 else "q2"
    n = int(sys.argv[2]) if len(sys.argv) > 2 else 20_000
    specs, source = QUERIES[qname](n=n)
    pipe, report = run_pipeline(
        specs, source, num_workers=4, heuristic="ct", collect_outputs=True
    )
    print(f"{qname}: {report}")
    print(f"egress tuples: {pipe.egress_count}; sample: {pipe.outputs[:2]}")


if __name__ == "__main__":
    main()
