"""Run the TPCx-BB streaming queries (paper §7) on the Engine API — print
the physical plan, then execute on the chosen backend.

  PYTHONPATH=src python examples/tpcxbb_stream.py [q1|q2|q3|q4|q15] [n_tuples] [thread|process]
"""
import sys

from repro.core import Engine, EngineConfig
from repro.streams.tpcxbb import QUERIES


def main():
    qname = sys.argv[1] if len(sys.argv) > 1 else "q2"
    n = int(sys.argv[2]) if len(sys.argv) > 2 else 20_000
    backend = sys.argv[3] if len(sys.argv) > 3 else "thread"
    specs, source = QUERIES[qname](n=n)
    engine = Engine(EngineConfig(
        backend=backend,
        num_workers="auto" if backend == "process" else 4,
        collect_outputs=True,
    ))
    plan = engine.plan(specs)
    print(plan.explain())
    result = engine.run(plan, source)
    print(f"{qname}: {result.report}")
    print(f"egress tuples: {result.egress_count}; sample: {result.outputs[:2]}")


if __name__ == "__main__":
    main()
