"""End-to-end LM training driver (deliverable b): trains an olmo-family model
on the ordered data pipeline with checkpointing.

Default is a fast CPU-sized config; pass --full for the ~100M-parameter run
(same code path, more steps — sized for a real accelerator).

  PYTHONPATH=src python examples/train_lm.py [--full]
"""
import dataclasses
import sys

import jax

from repro.configs import get_config
from repro.launch.train import main as train_main


def main():
    full = "--full" in sys.argv
    if full:
        # ~100M params: d=768, 12L, like a small GPT — few hundred steps
        import repro.configs.olmo_1b as olmo

        cfg = dataclasses.replace(
            olmo.CONFIG,
            name="olmo-100m",
            num_layers=12,
            d_model=768,
            num_heads=12,
            num_kv_heads=12,
            d_ff=3072,
            vocab_size=32000,
        )
        # register ad hoc through the train driver's smoke path is not
        # possible; drive the steps directly instead:
        import jax.numpy as jnp

        from repro.models.common import count_params, init_params
        from repro.train.data import DataConfig, OrderedTokenPipeline
        from repro.train.optimizer import OptConfig, init_opt_state
        from repro.train.train_step import make_train_step

        ocfg = OptConfig(peak_lr=3e-4, warmup_steps=20, decay_steps=300)
        print(f"training {cfg.name}: {count_params(cfg)/1e6:.0f}M params")
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = init_opt_state(ocfg, params)
        data = OrderedTokenPipeline(DataConfig(cfg.vocab_size, 512, 8))
        step_fn = jax.jit(make_train_step(cfg, ocfg), donate_argnums=(0, 1))
        for step in range(300):
            b = next(data)
            params, opt, m = step_fn(
                params, opt,
                {"tokens": jnp.asarray(b["tokens"]), "labels": jnp.asarray(b["labels"])},
            )
            if step % 10 == 0:
                print(f"step {step} loss={float(m['loss']):.4f}")
    else:
        train_main(
            ["--arch", "olmo-1b", "--smoke", "--steps", "30", "--batch", "4",
             "--seq", "128", "--ckpt-dir", "/tmp/repro_train_lm", "--ckpt-every", "10"]
        )


if __name__ == "__main__":
    main()
