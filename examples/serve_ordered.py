"""Ordered LM serving: batched requests, continuous batching, out-of-order
completion, in-order egress via the paper's non-blocking reorder buffer.

Compares the two scheduling policies — 'interleave' (pipelined flow, the
paper's winning strategy) vs 'prefill_first' (micro-batch style).

  PYTHONPATH=src python examples/serve_ordered.py
"""
import time

import numpy as np

import jax

from repro.configs import smoke_config
from repro.models.common import init_params
from repro.serve.engine import OrderedServingEngine


def run_policy(policy: str, params, cfg, n_requests=10):
    eng = OrderedServingEngine(
        cfg, params, max_slots=4, max_len=64, schedule=policy
    )
    rng = np.random.RandomState(0)
    serials = []
    for _ in range(n_requests):
        prompt = rng.randint(0, cfg.vocab_size, size=rng.randint(4, 16))
        serials.append(eng.submit(prompt, max_new_tokens=int(rng.randint(3, 12))))
    t0 = time.perf_counter()
    comps = eng.run_to_completion()
    wall = time.perf_counter() - t0
    assert [c.serial for c in comps] == sorted(serials), "ordering violated"
    toks = sum(len(c.tokens) for c in comps)
    return {
        "policy": policy,
        "wall_s": wall,
        "tokens": toks,
        "decode_steps": eng.stats["decode_steps"],
        "tok_per_decode_step": toks / max(eng.stats["decode_steps"], 1),
    }


def main():
    cfg = smoke_config("olmo-1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    for policy in ("interleave", "prefill_first"):
        r = run_policy(policy, params, cfg)
        print(
            f"{r['policy']:14s} wall={r['wall_s']:.2f}s tokens={r['tokens']} "
            f"decode_steps={r['decode_steps']} "
            f"tokens/decode-step={r['tok_per_decode_step']:.2f}"
        )
    print("ordered egress verified for both policies")


if __name__ == "__main__":
    main()
