"""Quickstart: compile -> plan -> execute on the Engine API, and check the
ordering guarantee end-to-end.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import Engine, EngineConfig, OpSpec


def main():
    # A 3-operator pipeline: stateless map -> partitioned running sum -> filter
    specs = [
        OpSpec("square", "stateless", lambda v: [v * v], cost_us=2),
        OpSpec(
            "running_sum_by_mod7",
            "partitioned",
            lambda s, k, v: (s + v, [(k, s + v)]),
            key_fn=lambda v: v % 7,
            num_partitions=16,
            init_state=lambda: 0,
            cost_us=3,
        ),
        OpSpec(
            "even_only", "stateless",
            lambda kv: [kv] if kv[1] % 2 == 0 else [], selectivity=0.5, cost_us=1,
        ),
    ]
    source = list(range(1, 5001))

    # compile → plan: the execution plan is a first-class, inspectable artifact
    engine = Engine(EngineConfig(
        backend="thread", num_workers=4, collect_outputs=True,
        thread={"heuristic": "ct"},
    ))
    plan = engine.plan(specs)
    print(plan.explain())
    print()

    # plan → execute: run to drain, uniform JobResult on every backend
    result = engine.run(plan, source)
    print(result.report)
    print("first outputs:", result.outputs[:5])

    # ordering check vs sequential oracle
    state = {}
    expected = []
    for v in source:
        vv = v * v
        k = vv % 7
        state[k] = state.get(k, 0) + vv
        if state[k] % 2 == 0:
            expected.append((k, state[k]))
    assert result.outputs == expected, "ordered-execution guarantee violated!"
    print(f"ordered execution verified over {len(expected)} outputs")


if __name__ == "__main__":
    main()
