"""The paper's fig. 1 example: high-mobility fraud detection over call-data
records, as an ordered streaming pipeline on the Engine API.

  filter(area) -> project(location record) -> compute speed (by phone)
  -> filter(speed > T) -> windowed count

  PYTHONPATH=src python examples/fraud_detection.py
"""
from repro.core import Engine, EngineConfig, OpSpec
from repro.streams.sources import cdr_stream

SPEED_T = 25.0  # cells/second — teleporting phones exceed this
WINDOW_S = 10.0


def main():
    def area_filter(cdr):
        return [cdr] if cdr.area_code == 408 else []

    def project(cdr):
        return [(cdr.caller, cdr.cell, cdr.ts)]

    def speed(state, key, rec):
        phone, cell, ts = rec
        out = []
        if state is not None:
            prev_cell, prev_ts = state
            dt = max(ts - prev_ts, 1e-6)
            v = abs(cell - prev_cell) / dt
            out = [(phone, v, ts)]
        return (cell, ts), out

    def fast_only(rec):
        return [rec] if rec[1] > SPEED_T else []

    def windowed_count(state, rec):
        window, count = state if state else (0, 0)
        w = int(rec[2] // WINDOW_S)
        if w != window:
            emitted = [(window, count)] if count else []
            return (w, 1), emitted
        return (window, count + 1), []

    specs = [
        OpSpec("area_filter", "stateless", area_filter, cost_us=2, selectivity=0.7),
        OpSpec("project", "stateless", project, cost_us=2),
        OpSpec(
            "speed", "partitioned", speed,
            key_fn=lambda r: r[0], num_partitions=128,
            init_state=lambda: None, cost_us=4, selectivity=0.9,
        ),
        OpSpec("fast_only", "stateless", fast_only, cost_us=2, selectivity=0.05),
        OpSpec("windowed_count", "stateful", windowed_count,
               init_state=lambda: None, cost_us=3, selectivity=0.1),
    ]
    engine = Engine(EngineConfig(
        backend="thread", num_workers=4, collect_outputs=True,
        thread={"heuristic": "ct"},
    ))
    plan = engine.plan(specs)
    print(plan.explain())
    result = engine.run(plan, cdr_stream(30_000, seed=7))
    print(result.report)
    alerts = result.outputs
    print(f"{len(alerts)} windows with high-mobility alerts; first 5: {alerts[:5]}")
    assert alerts, "expected some fraud windows with the seeded fraudsters"
    # windows must egress in order (ordered processing)
    windows = [w for (w, _) in alerts]
    assert windows == sorted(windows)
    print("ordered windowed alerts verified")


if __name__ == "__main__":
    main()
