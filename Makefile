# Convenience targets; the source of truth is scripts/verify.sh (ROADMAP.md).
.PHONY: verify test bench analyze docs-check

verify:
	./scripts/verify.sh

test:
	./scripts/verify.sh --fast

bench:
	PYTHONPATH=src python -m benchmarks.bench_core

analyze:
	PYTHONPATH=src python -m repro.analysis --check

docs-check:
	python scripts/check_links.py
