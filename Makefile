# Convenience targets; the source of truth is scripts/verify.sh (ROADMAP.md).
.PHONY: verify test bench

verify:
	./scripts/verify.sh

test:
	./scripts/verify.sh --fast

bench:
	PYTHONPATH=src python -m benchmarks.bench_core
