# Convenience targets; the source of truth is scripts/verify.sh (ROADMAP.md).
.PHONY: verify test bench analyze chaos docs-check

verify:
	./scripts/verify.sh

test:
	./scripts/verify.sh --fast

bench:
	PYTHONPATH=src python -m benchmarks.bench_core

analyze:
	PYTHONPATH=src python -m repro.analysis --check

chaos:
	PYTHONPATH=src python -m pytest tests/test_faults.py -q

docs-check:
	python scripts/check_links.py
