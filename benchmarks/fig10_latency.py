"""Fig. 10 — per-operator processing latency vs per-tuple cost for the two
partitioning schemes (uniform keys). HYBRID tracks the op cost (near-arrival-
order processing); PARTITIONED waits in the reorder buffer.
"""
from __future__ import annotations

from repro.core.simulate import SimConfig, SimOp, simulate

from .common import fmt_row, uniform_key_sampler

WORKERS = 8


def run(print_fn=print, n_tuples=8_000):
    print_fn("fig,scheme,cost_us,mean_latency_us,ratio_to_cost")
    for cost in (10.0, 100.0, 1000.0, 10000.0):
        n = min(n_tuples, int(4e8 / cost))  # keep sim time bounded
        for scheme, parts in (("hybrid", 100), ("partitioned", WORKERS)):
            ops = [
                SimOp("op", "partitioned", cost_us=cost, num_partitions=parts)
            ]
            r = simulate(
                ops, n,
                SimConfig(
                    num_workers=WORKERS, worklist_scheme=scheme, heuristic="lp"
                ),
                key_sampler=uniform_key_sampler(parts),
            )
            lat = r["mean_latency_us"]
            print_fn(
                fmt_row("fig10", scheme, int(cost), f"{lat:.1f}", f"{lat/cost:.2f}")
            )


if __name__ == "__main__":
    run()
