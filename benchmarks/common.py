"""Shared benchmark helpers. All figures print CSV rows; `run.py` aggregates.

Two measurement modes (DESIGN.md §7 fidelity note):
- sim: discrete-event simulation in virtual time (scaling figures — the
  1-core container cannot exhibit real parallel speedup)
- thread: the real threaded runtime (correctness + absolute single-core
  throughput)
"""
from __future__ import annotations

import math
import random


def gaussian_key_sampler(sigma: float, key_space: int = 10_000):
    """Paper fig. 9: range-partitioned keys sampled from N(0, sigma) scaled to
    the key space; lower sigma = more skew. The simulator's partitioner is
    ``key % num_partitions``, so pass key_space == num_partitions to model
    range partitioning (key IS the range bucket)."""

    def sample(rng: random.Random) -> int:
        # wrap (not clip) into [-1,1): sigma >> 1 converges to uniform,
        # sigma << 1 stays peaked — matching the paper's skew knob intent
        v = ((rng.gauss(0.0, sigma) + 1.0) % 2.0) - 1.0
        return int((v + 1.0) / 2.0 * (key_space - 1))

    return sample


def uniform_key_sampler(key_space: int = 10_000):
    def sample(rng: random.Random) -> int:
        return rng.randrange(key_space)

    return sample


def fmt_row(*cols) -> str:
    return ",".join(str(c) for c in cols)


def engine_run(graph, source, **knobs):
    """Run a chain (list of OpSpec) or ``(nodes, edges)`` graph on the
    Engine API with flat legacy knobs (strictly parsed — a typo'd knob
    raises ``ConfigError`` instead of silently measuring the wrong config);
    returns ``(handle, RunReport)`` like the deprecated one-shots did."""
    from repro.core import Engine, EngineConfig

    engine = Engine(EngineConfig.from_kwargs(**knobs))
    result = engine.run(graph, source)
    return result.handle(), result.report
