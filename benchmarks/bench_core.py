"""Core-runtime perf tracker: thread vs process backends, batching, staging.

Runs fixed wall-clock-sized (default ~10 s per config) fig. 8-style
CPU-bound synthetic queries (pure-Python compute stages, GIL-bound) through:

  - cpu_chain (3 stateless stages):
      backend=thread, batch_size=1   (the paper-faithful baseline)
      backend=thread, batch_size=32  (micro-batched tuple path)
      backend=process                (OS-process workers + shared-memory rings)
  - keyed_hotspot (SL → partitioned hot spot → SL — the interior-stateful
    shape the ingress-only plan cannot parallelize):
      backend=process, stages=1      (PR-2 ingress-only plan: hot op in the
                                      serial parent tail)
      backend=process, stages=auto   (staged plan: the keyed stage gets its
                                      own process worker group)

and writes ``BENCH_core.json`` (throughput, egress throughput, p99 latency,
busy fraction, a ``stages`` column, plus the headline ratios) so the perf
trajectory is tracked across PRs.  Each config's tuple count is
auto-calibrated from a short probe run so every row measures a comparable
wall-clock window.

Usage:
  PYTHONPATH=src python -m benchmarks.bench_core [--smoke] [--seconds S]
                                                 [--out PATH] [--workers N]

``--smoke`` shrinks the window to ~1 s per config — used by ``make verify``
to keep the perf plumbing from rotting without a 60 s bill.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time

from repro.core import run_pipeline
from repro.streams.parametric import cpu_bound_chain, keyed_hotspot_chain

SPIN = 100  # ~24 µs of GIL-bound work per tuple across the 3-stage chain
STAGES = 3
HOT_SPIN = 1200  # keyed hot spot: ~96 µs/tuple in the partitioned op alone

WORKLOADS = {
    "cpu_chain": lambda: cpu_bound_chain(stages=STAGES, spin=SPIN),
    "keyed_hotspot": lambda: keyed_hotspot_chain(spin_edge=30, spin_hot=HOT_SPIN),
}

CONFIGS = (
    {"workload": "cpu_chain", "backend": "thread", "batch_size": 1},
    {"workload": "cpu_chain", "backend": "thread", "batch_size": 32},
    {"workload": "cpu_chain", "backend": "process", "batch_size": 1},
    # The hotspot pair measures stage *topology*, not fan-out: pin the
    # per-stage worker-group size to 2 so the A/B stays apples-to-apples
    # regardless of --workers (and of a small container's core count).
    {"workload": "keyed_hotspot", "backend": "process", "batch_size": 32,
     "stages": 1, "workers": 2},
    {"workload": "keyed_hotspot", "backend": "process", "batch_size": 32,
     "stages": None, "workers": 2},  # None = auto: cut as deep as possible
)


def _run_once(cfg: dict, n: int, workers: int):
    kw = dict(
        num_workers=cfg.get("workers", workers),
        backend=cfg["backend"],
        batch_size=cfg["batch_size"],
    )
    if "stages" in cfg:
        kw["stages"] = cfg["stages"]
    return run_pipeline(WORKLOADS[cfg["workload"]](), range(n), **kw)


def _run_config(cfg: dict, seconds: float, workers: int):
    workers = cfg.get("workers", workers)
    # probe: size the real run to ~`seconds` of wall clock
    probe_n = 2000
    _, probe = _run_once(cfg, probe_n, workers)
    n = max(int(probe.throughput * seconds), probe_n)
    pipe, report = _run_once(cfg, n, workers)
    if not (0.7 * seconds <= report.wall_time <= 1.3 * seconds):
        # the short probe misjudged the sustained rate (startup effects);
        # rescale once so every config measures a comparable window
        scale = min(max(seconds / max(report.wall_time, 1e-9), 0.25), 4.0)
        n = max(int(n * scale), probe_n)
        pipe, report = _run_once(cfg, n, workers)
    return {
        "workload": cfg["workload"],
        "backend": cfg["backend"],
        "batch_size": cfg["batch_size"],
        # process stages the planner actually cut (1 = ingress-only plan;
        # null for the thread backend, which has no process stages)
        "stages": getattr(pipe, "num_stages", None),
        "workers": workers,
        "tuples": n,
        "wall_s": round(report.wall_time, 3),
        "throughput_per_s": round(report.throughput, 1),
        "egress_throughput_per_s": round(report.egress_throughput, 1),
        "p99_latency_ms": round(report.p99_latency * 1e3, 3),
        "mean_latency_ms": round(report.mean_latency * 1e3, 3),
        "busy_frac": round(report.worker_busy_frac, 3),
    }


def run(seconds: float = 10.0, workers: int = 4, out: str = "BENCH_core.json",
        print_fn=print):
    rows = []
    for cfg in CONFIGS:
        row = _run_config(cfg, seconds, workers)
        rows.append(row)
        stages = "-" if row["stages"] is None else row["stages"]
        print_fn(
            f"{row['workload']:>14} {row['backend']:>7} "
            f"batch={row['batch_size']:<3} stages={stages:<2} "
            f"thru={row['throughput_per_s']:>10,.0f}/s "
            f"p99={row['p99_latency_ms']:.3f}ms busy={row['busy_frac']:.2f} "
            f"({row['tuples']} tuples / {row['wall_s']}s)"
        )

    def thru(workload, backend, batch, staged=None):
        for r in rows:
            if (
                r["workload"] == workload
                and r["backend"] == backend
                and r["batch_size"] == batch
                and (
                    staged is None
                    or (r["stages"] != 1 if staged else r["stages"] == 1)
                )
            ):
                return r["throughput_per_s"]
        return 0.0

    ratios = {
        "process_vs_thread": round(
            thru("cpu_chain", "process", 1) /
            max(thru("cpu_chain", "thread", 1), 1e-9), 3,
        ),
        "thread_batch32_vs_batch1": round(
            thru("cpu_chain", "thread", 32) /
            max(thru("cpu_chain", "thread", 1), 1e-9), 3,
        ),
        # The tentpole ratio: staged plan vs the PR-2 ingress-only plan on
        # the same workload.  The auto plan cuts SL|PS|SL into 2 stages (the
        # trailing stateless run folds into the keyed stage).
        "staged_vs_ingress_process": round(
            thru("keyed_hotspot", "process", 32, staged=True) /
            max(thru("keyed_hotspot", "process", 32, staged=False), 1e-9), 3,
        ),
    }
    doc = {
        "meta": {
            "workloads": {
                "cpu_chain": f"fig8-style CPU-bound chain ({STAGES} stages, "
                             f"spin={SPIN})",
                "keyed_hotspot": f"SL(spin=30) -> PS(spin={HOT_SPIN}, keyed) "
                                 f"-> SL(spin=30) interior hot spot",
            },
            "seconds_per_config": seconds,
            "cpu_count": os.cpu_count(),
            "python": platform.python_version(),
            "unix_time": int(time.time()),
        },
        "results": rows,
        "ratios": ratios,
    }
    with open(out, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print_fn(
        f"ratios: process/thread={ratios['process_vs_thread']}x  "
        f"batch32/batch1={ratios['thread_batch32_vs_batch1']}x  "
        f"staged/ingress={ratios['staged_vs_ingress_process']}x  -> {out}"
    )
    return doc


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="~1 s per config (CI plumbing check)")
    ap.add_argument("--seconds", type=float, default=None,
                    help="wall-clock window per config (default 10, smoke 1)")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--out", default="BENCH_core.json")
    args = ap.parse_args(argv)
    seconds = args.seconds if args.seconds is not None else (1.0 if args.smoke else 10.0)
    run(seconds=seconds, workers=args.workers, out=args.out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
