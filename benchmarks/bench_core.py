"""Core-runtime perf tracker: thread vs process backends, batch 1 vs 32.

Runs a fixed wall-clock-sized (default ~10 s per config) fig. 8-style
CPU-bound synthetic query (pure-Python compute stages, GIL-bound) through:

  - backend=thread, batch_size=1   (the paper-faithful baseline)
  - backend=thread, batch_size=32  (micro-batched tuple path)
  - backend=process                (OS-process workers + shared-memory rings)

and writes ``BENCH_core.json`` (throughput, egress throughput, p99 latency,
busy fraction, plus the two headline ratios) so the perf trajectory is
tracked across PRs.  Each config's tuple count is auto-calibrated from a
short probe run so every row measures a comparable wall-clock window.

Usage:
  PYTHONPATH=src python -m benchmarks.bench_core [--smoke] [--seconds S]
                                                 [--out PATH] [--workers N]

``--smoke`` shrinks the window to ~1 s per config — used by ``make verify``
to keep the perf plumbing from rotting without a 30 s bill.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time

from repro.core import run_pipeline
from repro.streams.parametric import cpu_bound_chain

SPIN = 100  # ~24 µs of GIL-bound work per tuple across the 3-stage chain
STAGES = 3
CONFIGS = (
    {"backend": "thread", "batch_size": 1},
    {"backend": "thread", "batch_size": 32},
    {"backend": "process", "batch_size": 1},
)


def _run_config(backend: str, batch_size: int, seconds: float, workers: int):
    kw = dict(num_workers=workers, backend=backend, batch_size=batch_size)
    # probe: size the real run to ~`seconds` of wall clock
    probe_n = 2000
    _, probe = run_pipeline(cpu_bound_chain(stages=STAGES, spin=SPIN),
                            range(probe_n), **kw)
    n = max(int(probe.throughput * seconds), probe_n)
    _, report = run_pipeline(cpu_bound_chain(stages=STAGES, spin=SPIN),
                             range(n), **kw)
    if not (0.7 * seconds <= report.wall_time <= 1.3 * seconds):
        # the short probe misjudged the sustained rate (startup effects);
        # rescale once so every config measures a comparable window
        scale = min(max(seconds / max(report.wall_time, 1e-9), 0.25), 4.0)
        n = max(int(n * scale), probe_n)
        _, report = run_pipeline(cpu_bound_chain(stages=STAGES, spin=SPIN),
                                 range(n), **kw)
    return {
        "backend": backend,
        "batch_size": batch_size,
        "workers": workers,
        "tuples": n,
        "wall_s": round(report.wall_time, 3),
        "throughput_per_s": round(report.throughput, 1),
        "egress_throughput_per_s": round(report.egress_throughput, 1),
        "p99_latency_ms": round(report.p99_latency * 1e3, 3),
        "mean_latency_ms": round(report.mean_latency * 1e3, 3),
        "busy_frac": round(report.worker_busy_frac, 3),
    }


def run(seconds: float = 10.0, workers: int = 4, out: str = "BENCH_core.json",
        print_fn=print):
    rows = []
    for cfg in CONFIGS:
        row = _run_config(cfg["backend"], cfg["batch_size"], seconds, workers)
        rows.append(row)
        print_fn(
            f"{row['backend']:>7} batch={row['batch_size']:<3} "
            f"thru={row['throughput_per_s']:>10,.0f}/s "
            f"p99={row['p99_latency_ms']:.3f}ms busy={row['busy_frac']:.2f} "
            f"({row['tuples']} tuples / {row['wall_s']}s)"
        )

    def thru(backend, batch):
        for r in rows:
            if r["backend"] == backend and r["batch_size"] == batch:
                return r["throughput_per_s"]
        return 0.0

    ratios = {
        "process_vs_thread": round(thru("process", 1) / max(thru("thread", 1), 1e-9), 3),
        "thread_batch32_vs_batch1": round(
            thru("thread", 32) / max(thru("thread", 1), 1e-9), 3
        ),
    }
    doc = {
        "meta": {
            "workload": f"fig8-style CPU-bound chain ({STAGES} stages, spin={SPIN})",
            "seconds_per_config": seconds,
            "cpu_count": os.cpu_count(),
            "python": platform.python_version(),
            "unix_time": int(time.time()),
        },
        "results": rows,
        "ratios": ratios,
    }
    with open(out, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print_fn(
        f"ratios: process/thread={ratios['process_vs_thread']}x  "
        f"batch32/batch1={ratios['thread_batch32_vs_batch1']}x  -> {out}"
    )
    return doc


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="~1 s per config (CI plumbing check)")
    ap.add_argument("--seconds", type=float, default=None,
                    help="wall-clock window per config (default 10, smoke 1)")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--out", default="BENCH_core.json")
    args = ap.parse_args(argv)
    seconds = args.seconds if args.seconds is not None else (1.0 if args.smoke else 10.0)
    run(seconds=seconds, workers=args.workers, out=args.out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
