"""Core-runtime perf tracker: thread vs process backends, batching, staging,
cost-model worker allocation.

Runs fixed wall-clock-sized (default ~10 s per config) fig. 8-style
CPU-bound synthetic queries (pure-Python compute stages, GIL-bound) through:

  - cpu_chain (3 stateless stages):
      backend=thread, batch_size=1   (the paper-faithful baseline)
      backend=thread, batch_size=32  (micro-batched tuple path)
      backend=process                (OS-process workers + shared-memory rings)
  - keyed_hotspot (SL → partitioned hot spot → SL — the interior-stateful
    shape the ingress-only plan cannot parallelize):
      backend=process, stages=1      (PR-2 ingress-only plan: hot op in the
                                      serial parent tail)
      backend=process, stages=auto   (staged plan: the keyed stage gets its
                                      own process worker group)
  - recovery (the keyed_hotspot shape under a seeded 1-kill schedule):
      backend=process, checkpointed   (the keyed stage's worker 0 is
                                      SIGKILLed mid-run and restored from
                                      the last epoch checkpoint; the row
                                      tracks goodput under the fault and the
                                      supervisor-measured recovery latency)
  - skewed_stages (SL(hot) → PS(cold) — a pipeline whose load is
    concentrated in one stage):
      workers=1        (flat: the even split of the default worker budget
                        across the two data-parallel stages — the hot stage
                        is starved exactly as a flat ``num_workers`` starves
                        any skewed pipeline)
      workers="auto"   (cost-model allocation: the calibrated budget
                        division gives the hot stage the spare workers)
    The pair is measured INTERLEAVED (flat/auto alternating over several
    rounds, throughput aggregated per config) so the ``auto_vs_flat_process``
    ratio cancels host-speed drift on small/noisy boxes.

  - columnar_device (SL widen -> two device affine stages, NumPy reference
    kernel): the SAME chain with ``columnar=False`` (pickled units; the
    device workers convert tuples to columns serially) vs ``columnar=True``
    (TAG_COLBLOCK spans end-to-end: parallel block encode upstream,
    zero-copy device ingest, block pass-through between device stages).
    Measured INTERLEAVED like skewed_stages so the
    ``columnar_vs_pickle_process`` ratio cancels host-speed drift (still
    budget ~±20% run-to-run on shared vCPUs — see docs/columnar.md).
  - device_offload (widen -> one device stage on the jax/pallas kernel,
    columnar ingest): the offload smoke row — proves the pallas dispatch
    path end-to-end and tracks its throughput; falls back to the NumPy
    reference kernel (and says so in the row) when jax is absent.

  - serving / elastic_serving (open-loop multiplexed sessions): the serving
    row tracks coordinated-omission-free tail latency at 50% of probed
    capacity; the elastic_serving row replays a bursty trace against static
    vs traffic-reactive widths (SessionMux load signals driving the
    TrafficMonitor's grow/shrink of the sid-partitioned stage) and records
    the reactive side's resize counters next to both sides' percentiles.

and writes ``BENCH_core.json`` (throughput, egress throughput, p99 latency,
busy fraction, a ``stages`` column, plus the headline ratios) so the perf
trajectory is tracked across PRs.  Each config's tuple count is
auto-calibrated from a short probe run so every row measures a comparable
wall-clock window.

Usage:
  PYTHONPATH=src python -m benchmarks.bench_core [--smoke] [--seconds S]
                                                 [--out PATH] [--workers N]

``--smoke`` shrinks the window to ~1 s per config — used by ``make verify``
to keep the perf plumbing from rotting without a 60 s bill.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time

from repro.streams.parametric import (
    cpu_bound_chain,
    keyed_hotspot_chain,
    skewed_stage_chain,
)

from .common import engine_run

SPIN = 100  # ~24 µs of GIL-bound work per tuple across the 3-stage chain
STAGES = 3
HOT_SPIN = 1200  # keyed hot spot: ~96 µs/tuple in the partitioned op alone
SKEW_HOT = 10000  # skewed_stages hot stage: heavy per-tuple compute so the
SKEW_COLD = 30  # allocation effect dominates exchange/plumbing overhead

COL_WIDTH = 12  # i8 columns per row on the columnar rows (96-byte payload)
COL_BATCH = 256  # micro-batch = device batch on the columnar A/B: units big
#                  enough that codec cost, not per-unit exchange plumbing,
#                  is what the pair contrasts (at batch 32 both sides mostly
#                  measure the router and the ratio collapses to ~1)


def _col_widen(v):
    # intentionally cheap widening: the columnar rows measure the *wire*
    # (pickled units vs TAG_COLBLOCK spans), so per-tuple compute stays
    # negligible next to codec + exchange costs
    return [(v,) * COL_WIDTH]


def _columnar_device_chain(backend: str, kernel: str, ndev: int = 2):
    from repro.columnar import Schema, device_op
    from repro.core.operators import OpSpec

    schema = Schema.of(*(["i8"] * COL_WIDTH))
    ops = [OpSpec("widen", "stateless", _col_widen, cost_us=1.0)]
    for i, (a, b) in zip(range(ndev), ((3, -1), (1, 5))):
        ops.append(device_op(
            f"dev{i}", kernel, schema, params={"a": a, "b": b},
            backend=backend, cost_us=2.0,
        ))
    return ops


def _offload_backend():
    """(backend, kernel) for the device_offload row: pallas when jax is
    importable, the NumPy reference otherwise (the row records which)."""
    from repro.columnar import have_jax

    if have_jax():
        return "jax", "affine_pallas"
    return "numpy", "affine"


WORKLOADS = {
    "cpu_chain": lambda: cpu_bound_chain(stages=STAGES, spin=SPIN),
    "keyed_hotspot": lambda: keyed_hotspot_chain(spin_edge=30, spin_hot=HOT_SPIN),
    "skewed_stages": lambda: skewed_stage_chain(
        spin_hot=SKEW_HOT, spin_cold=SKEW_COLD
    ),
    "columnar_device": lambda: _columnar_device_chain("numpy", "affine"),
    "device_offload": lambda: _columnar_device_chain(
        *_offload_backend(), ndev=1
    ),
}

CONFIGS = (
    {"workload": "cpu_chain", "backend": "thread", "batch_size": 1},
    {"workload": "cpu_chain", "backend": "thread", "batch_size": 32},
    {"workload": "cpu_chain", "backend": "process", "batch_size": 1},
    # The hotspot pair measures stage *topology*, not fan-out: pin the
    # per-stage worker-group size to 2 so the A/B stays apples-to-apples
    # regardless of --workers (and of a small container's core count).
    {"workload": "keyed_hotspot", "backend": "process", "batch_size": 32,
     "stages": 1, "workers": 2},
    {"workload": "keyed_hotspot", "backend": "process", "batch_size": 32,
     "stages": None, "workers": 2},  # None = auto: cut as deep as possible
)

# The allocation A/B: both sides get the SAME worker budget (the auto
# default, cores+1).  Flat spends it as an even per-stage split over the
# chain's two data-parallel stages (budget // 2 each — the remainder is
# unusable, which IS flat's deficiency on an odd budget); auto divides it by
# predicted load, concentrating the spare on the hot stage.  parent_idle_cap
# trades ~ms of drain latency for supervisor CPU the hot worker group needs
# on a 2-core box — applied to BOTH sides.
AB_ROUNDS = 4


def _ab_configs():
    from repro.core import costmodel

    budget = costmodel.default_budget()
    return (
        {"workload": "skewed_stages", "backend": "process", "batch_size": 32,
         "workers": max(1, budget // 2), "parent_idle_cap": 2e-3,
         "worker_budget": budget},
        {"workload": "skewed_stages", "backend": "process", "batch_size": 32,
         "workers": "auto", "parent_idle_cap": 2e-3,
         "worker_budget": budget},
    )


def _run_once(cfg: dict, n: int, workers: int):
    """One measured run on the Engine surface (compile → plan-on-the-fly →
    execute); returns ``(handle, report)`` like the legacy one-shot did."""
    kw = dict(
        num_workers=cfg.get("workers", workers),
        backend=cfg["backend"],
        batch_size=cfg["batch_size"],
    )
    if "stages" in cfg:
        kw["stages"] = cfg["stages"]
    if "parent_idle_cap" in cfg:
        kw["parent_idle_cap"] = cfg["parent_idle_cap"]
    if cfg.get("workers") == "auto" and "worker_budget" in cfg:
        kw["worker_budget"] = cfg["worker_budget"]
    for key in ("columnar", "device_batch", "device_backend",
                "device_inflight", "max_inflight", "reorder_size"):
        if key in cfg:
            kw[key] = cfg[key]
    return engine_run(WORKLOADS[cfg["workload"]](), range(n), **kw)


def _run_config(cfg: dict, seconds: float, workers: int):
    workers = cfg.get("workers", workers)
    # probe: size the real run to ~`seconds` of wall clock
    probe_n = 2000
    _, probe = _run_once(cfg, probe_n, workers)
    n = max(int(probe.throughput * seconds), probe_n)
    pipe, report = _run_once(cfg, n, workers)
    if not (0.7 * seconds <= report.wall_time <= 1.3 * seconds):
        # the short probe misjudged the sustained rate (startup effects);
        # rescale once so every config measures a comparable window
        scale = min(max(seconds / max(report.wall_time, 1e-9), 0.25), 4.0)
        n = max(int(n * scale), probe_n)
        pipe, report = _run_once(cfg, n, workers)
    return {
        "workload": cfg["workload"],
        "backend": cfg["backend"],
        "batch_size": cfg["batch_size"],
        # process stages the planner actually cut (1 = ingress-only plan;
        # null for the thread backend, which has no process stages)
        "stages": getattr(pipe, "num_stages", None),
        "workers": workers,
        "tuples": n,
        "wall_s": round(report.wall_time, 3),
        "throughput_per_s": round(report.throughput, 1),
        "egress_throughput_per_s": round(report.egress_throughput, 1),
        "p99_latency_ms": round(report.p99_latency * 1e3, 3),
        "mean_latency_ms": round(report.mean_latency * 1e3, 3),
        "busy_frac": round(report.worker_busy_frac, 3),
    }


RECOVERY_SPIN = 300  # keyed hot op: enough work that recovery cost is visible
RECOVERY_CKPT = 512  # epoch length (serials) for the recovery row


def _run_recovery(seconds: float, workers: int):
    """Goodput + recovery latency under a seeded 1-kill schedule.

    A clean pass sizes the run and provides the no-fault baseline; the
    measured pass SIGKILLs the keyed stage's worker 0 at the stream midpoint.
    The supervisor restores the group from the last epoch checkpoint and
    replays, so every tuple still egresses exactly once — ``goodput`` is the
    end-to-end throughput *including* the recovery stall, and
    ``recovery_latency_ms`` is the supervisor-measured halt-to-replay time.
    """
    from repro.core import FaultOptions, FaultPlan, FaultSpec

    def chain():
        return keyed_hotspot_chain(spin_edge=30, spin_hot=RECOVERY_SPIN)

    kw = dict(backend="process", num_workers=2, batch_size=32,
              checkpoint_interval=RECOVERY_CKPT)
    probe_n = 2000
    _, probe = engine_run(chain(), range(probe_n), **kw)
    n = max(int(probe.throughput * seconds), probe_n)
    _, clean = engine_run(chain(), range(n), **kw)
    plan = FaultPlan(
        specs=[FaultSpec(kind="kill", stage=1, worker=0,
                         serial=max(n // 2, 1))],
        seed=7,
    )
    handle, report = engine_run(
        chain(), range(n), faults=FaultOptions(plan=plan), **kw
    )
    result = handle.result
    assert result.egress_count == n, (
        f"recovery lost tuples: {result.egress_count}/{n}"
    )
    return {
        "workload": "recovery",
        "backend": "process",
        "batch_size": 32,
        "stages": getattr(handle, "num_stages", None),
        "workers": 2,
        "checkpoint_interval": RECOVERY_CKPT,
        "tuples": n,
        "wall_s": round(report.wall_time, 3),
        "throughput_per_s": round(report.throughput, 1),
        "egress_throughput_per_s": round(report.egress_throughput, 1),
        "p99_latency_ms": round(report.p99_latency * 1e3, 3),
        "mean_latency_ms": round(report.mean_latency * 1e3, 3),
        "busy_frac": round(report.worker_busy_frac, 3),
        "clean_throughput_per_s": round(clean.throughput, 1),
        "restarts": result.restarts,
        "recoveries": result.recoveries,
        "recovery_latency_ms": round(handle.recovery_time_s * 1e3, 3),
    }


SERVING_SESSIONS = 8  # concurrent ordered sessions multiplexed per runtime
SERVING_UTIL = 0.5  # offered load as a fraction of probed capacity


def _run_serving(seconds: float, workers: int):
    """Open-loop serving row: ``SERVING_SESSIONS`` concurrent sessions
    multiplexed onto one planned runtime (``repro.serve.SessionMux``), fed
    Poisson arrivals at ~``SERVING_UTIL`` of probed capacity.  Latency is
    coordinated-omission-free (measured from each request's *scheduled*
    arrival), so p99/p999 reflect queueing under sustained load — the
    fig.10-style serving metric — not closed-loop drain time."""
    from repro.core.api import Engine, EngineConfig
    from repro.serve import ArrivalConfig, MuxConfig, SessionMux, run_open_loop

    def make_mux():
        eng = Engine(EngineConfig(
            backend="thread", num_workers=workers, batch_size=8,
        ))
        return SessionMux(
            eng, cpu_bound_chain(stages=STAGES, spin=SPIN),
            config=MuxConfig(max_sessions=SERVING_SESSIONS),
        )

    # probe: saturating offered load -> achieved rate ~= mux capacity.
    # The warmup prefix keeps the cold-start ramp (thread spin-up, first
    # plan, estimator warm-up) out of the capacity window: without it the
    # probe under-reads capacity and the measured run is offered less load
    # than SERVING_UTIL claims.  The probe must be big enough that the
    # steady window is 100s of ms — at ~25k/s a 250-request probe leaves a
    # ~30 ms window where completion-timestamp clumping (the pump drains
    # outputs in bursts) inflates the rate 2-20x.
    with make_mux() as mux:
        probe = run_open_loop(
            mux, sessions=SERVING_SESSIONS, requests=2000, warmup=400,
            arrivals=ArrivalConfig(shape="poisson", rate=1e6, seed=3),
        )
    capacity = max(probe.achieved_rate, 1.0)
    offered = capacity * SERVING_UTIL
    per_session = max(int(offered * seconds / SERVING_SESSIONS), 50)
    with make_mux() as mux:
        rep = run_open_loop(
            mux, sessions=SERVING_SESSIONS, requests=per_session,
            arrivals=ArrivalConfig(
                shape="poisson", rate=offered / SERVING_SESSIONS, seed=11,
            ),
        )
    return {
        "workload": "serving",
        "backend": "thread",
        "batch_size": 8,
        "stages": None,
        "workers": workers,
        "sessions": SERVING_SESSIONS,
        "arrivals": "poisson",
        "open_loop": True,
        "capacity_per_s": round(capacity, 1),
        "offered_rate_per_s": round(rep.offered_rate, 1),
        "achieved_rate_per_s": round(rep.achieved_rate, 1),
        "tuples": rep.requests,
        "wall_s": round(rep.duration_s, 3),
        "throughput_per_s": round(rep.achieved_rate, 1),
        "p50_latency_ms": round(rep.p50 * 1e3, 3),
        "p99_latency_ms": round(rep.p99 * 1e3, 3),
        "p999_latency_ms": round(rep.p999 * 1e3, 3),
        "mean_latency_ms": round(rep.mean * 1e3, 3),
    }


ELASTIC_SESSIONS = 6  # concurrent sessions on the elastic serving row
ELASTIC_PARTITIONS = 4  # sid partitions (= keyed-stage elastic ceiling)
ELASTIC_SPIN = 20000  # stateful accumulator: ~1 ms/tuple, so the keyed
#                       *worker* is the bottleneck (well under the parent
#                       supervisor's shuttle capacity) and stage width
#                       genuinely sets end-to-end capacity — the property
#                       the grow/shrink A/B is about
ELASTIC_BUDGET = 3  # worker budget: 1 spare over the 2 stages' floor
ELASTIC_UTIL = 0.4  # mean offered load as a fraction of probed capacity
#                     (low enough that the mean stays sustainable even if
#                     the host runs ~1.5x slower than the probe sampled —
#                     shared-vCPU speed regimes shift on ~10 s timescales)
ELASTIC_BURST = 4.0  # burst peak = BURST x mean = 1.6 x capacity: deep
#                      enough that width 1 falls behind even if the probe
#                      *under*-sampled capacity by ~1.5x, while width 2
#                      still has drain headroom at the nominal calibration
ELASTIC_DUTY = 0.225  # fraction of each period spent at the burst rate
#                       (duty x factor = 0.9 < 1, so the square wave's
#                       analytic mean is exactly the nominal rate)
ELASTIC_PERIOD = 4.0  # seconds per burst/trough cycle: a ~1 s burst
#                       dwarfs both the policy's detection lag (~0.3 s:
#                       signal interval + patience) and the ~50-150 ms
#                       quiesce stall a grow costs, so the extra width
#                       has most of the burst left to repay the stall —
#                       shallow bursts end before the grow lands and
#                       measure nothing but the stall


def _elastic_chain():
    """SL(edge) -> stateful(accsum): the mux converts the stateful op into
    a sid-partitioned keyed stage (``ELASTIC_PARTITIONS`` partitions) —
    exactly the stage the traffic policy grows and shrinks."""
    from repro.core.operators import OpSpec
    from repro.streams.parametric import cpu_bound_stateless

    def acc(state, v):
        x = float(v)
        for _ in range(ELASTIC_SPIN):
            x = (x * 1.0000001 + 1.31) % 97.0
        return (state or 0) + 1, [x]

    return [
        cpu_bound_stateless("edge", spin=30),
        OpSpec("accsum", "stateful", acc, init_state=lambda: 0,
               cost_us=ELASTIC_SPIN * 0.08),
    ]


def _elastic_mux(reactive: bool):
    from repro.core.api import Engine, EngineConfig, ProcessOptions
    from repro.serve import MuxConfig, SessionMux

    # replan_interval parks the occupancy (skew) monitor so the row
    # isolates the *traffic* loop; the reactive side gets aggressive dials
    # (short interval, patience 1, brief cooldown) because the bursty
    # trace compresses a diurnal cycle into ~1 s periods.
    # max_inflight bounds the quiesce stall a resize must drain (8 units
    # x io_batch 8 x ~1 ms/tuple ~= 64 ms), keeping honest resizes well
    # inside the 0.5 s p99-guard budget
    popts = dict(worker_budget=ELASTIC_BUDGET, replan_interval=600.0,
                 max_inflight=8)
    if reactive:
        popts.update(
            traffic_elastic=True, traffic_interval=0.15,
            traffic_grow_util=0.65, traffic_shrink_util=0.30,
            traffic_patience=1, traffic_cooldown=0.6,
            resize_latency_budget=0.5,
        )
    else:
        popts.update(elastic=False)  # static widths: the control arm
    eng = Engine(EngineConfig(
        backend="process", num_workers=1, batch_size=2,
        process=ProcessOptions(**popts),
    ))
    return SessionMux(
        eng, _elastic_chain(),
        config=MuxConfig(
            max_sessions=ELASTIC_SESSIONS,
            state_partitions=ELASTIC_PARTITIONS,
            load_signal_interval=0.05,
        ),
    )


def _run_elastic_serving(seconds: float, workers: int):
    """Traffic-reactive elasticity A/B: the same bursty open-loop trace
    (square-wave offered load: ``ELASTIC_DUTY`` of each second at
    ``ELASTIC_BURST``x the mean, a deep trough in between) is replayed
    against *static* widths and against the closed loop — SessionMux load
    signals feeding the TrafficMonitor, which grows the sid-partitioned
    stateful stage into the burst and shrinks it back in the trough
    (hysteresis + cooldown + the resize-latency p99 guard).  The row
    carries the reactive side's grow/shrink/abort/revert counters and both
    sides' percentiles."""
    from repro.serve import ArrivalConfig, run_open_loop

    window = max(seconds, 2.25 * ELASTIC_PERIOD)  # >= 2 full cycles
    # Median of three flood probes: the shared-vCPU host shifts speed
    # regimes on ~10 s timescales (observed 1.5-2x capacity swings between
    # back-to-back probes), and a single sample mis-calibrates the whole
    # trace.  The bursty trace itself tolerates a further ~1.5x drift in
    # either direction (see ELASTIC_UTIL / ELASTIC_BURST).
    samples = []
    for _ in range(3):
        with _elastic_mux(reactive=False) as mux:
            probe = run_open_loop(
                mux, sessions=ELASTIC_SESSIONS, requests=90, warmup=24,
                arrivals=ArrivalConfig(shape="poisson", rate=1e6, seed=5),
            )
        samples.append(probe.achieved_rate)
    capacity = max(sorted(samples)[1], 1.0)
    offered = capacity * ELASTIC_UTIL
    per_session = max(int(offered * window / ELASTIC_SESSIONS), 40)
    arrivals = ArrivalConfig(
        shape="bursty", rate=offered / ELASTIC_SESSIONS,
        burst_factor=ELASTIC_BURST, burst_duty=ELASTIC_DUTY,
        period_s=ELASTIC_PERIOD, seed=17,
    )
    reports, counters = {}, {}
    for mode, reactive in (("static", False), ("reactive", True)):
        with _elastic_mux(reactive=reactive) as mux:
            reports[mode] = run_open_loop(
                mux, sessions=ELASTIC_SESSIONS, requests=per_session,
                arrivals=arrivals,
            )
            counters[mode] = mux._inner.stats()
    static, reactive_rep = reports["static"], reports["reactive"]
    rs = counters["reactive"]
    stalls = rs.get("resize_stalls") or []
    return {
        "workload": "elastic_serving",
        "backend": "process",
        "batch_size": 2,
        "stages": len(rs.get("stage_widths") or []) or None,
        "workers": 1,
        "worker_budget": ELASTIC_BUDGET,
        "sessions": ELASTIC_SESSIONS,
        "arrivals": "bursty",
        "open_loop": True,
        "capacity_per_s": round(capacity, 1),
        "offered_rate_per_s": round(reactive_rep.offered_rate, 1),
        "achieved_rate_per_s": round(reactive_rep.achieved_rate, 1),
        "tuples": reactive_rep.requests,
        "wall_s": round(reactive_rep.duration_s, 3),
        "throughput_per_s": round(reactive_rep.achieved_rate, 1),
        "p50_latency_ms": round(reactive_rep.p50 * 1e3, 3),
        "p99_latency_ms": round(reactive_rep.p99 * 1e3, 3),
        "p999_latency_ms": round(reactive_rep.p999 * 1e3, 3),
        "mean_latency_ms": round(reactive_rep.mean * 1e3, 3),
        "static_p50_latency_ms": round(static.p50 * 1e3, 3),
        "static_p99_latency_ms": round(static.p99 * 1e3, 3),
        "final_stage_widths": rs.get("stage_widths"),
        "grows": rs.get("grows", 0),
        "shrinks": rs.get("shrinks", 0),
        "resize_aborts": rs.get("resize_aborts", 0),
        "resize_reverts": rs.get("resize_reverts", 0),
        "max_resize_stall_ms": (
            round(max(stalls) * 1e3, 3) if stalls else 0.0
        ),
    }


def _run_ab_configs(seconds: float, workers: int):
    """Measure the skewed-stages pair interleaved: flat/auto alternate over
    ``AB_ROUNDS`` rounds and each config's throughput is aggregated across
    its rounds.  Back-to-back alternation means both sides sample the same
    host-speed regime, so the ratio is robust to machine drift that dwarfs
    the effect on shared/bursted vCPUs."""
    flat_cfg, auto_cfg = _ab_configs()
    probe_n = 1500
    _, probe = _run_once(flat_cfg, probe_n, workers)
    per_round = max(
        int(probe.throughput * seconds / AB_ROUNDS), probe_n
    )
    agg = {id(flat_cfg): [0, 0.0, None], id(auto_cfg): [0, 0.0, None]}
    for _ in range(AB_ROUNDS):
        for cfg in (flat_cfg, auto_cfg):
            pipe, report = _run_once(cfg, per_round, workers)
            slot = agg[id(cfg)]
            slot[0] += report.tuples_in
            slot[1] += report.wall_time
            slot[2] = (pipe, report)
    rows = []
    for cfg in (flat_cfg, auto_cfg):
        tuples, wall, (pipe, report) = agg[id(cfg)]
        rows.append({
            "workload": cfg["workload"],
            "backend": cfg["backend"],
            "batch_size": cfg["batch_size"],
            "stages": getattr(pipe, "num_stages", None),
            "workers": cfg["workers"],
            "stage_widths": getattr(pipe, "stage_widths", lambda: None)(),
            "interleaved_rounds": AB_ROUNDS,
            "tuples": tuples,
            "wall_s": round(wall, 3),
            "throughput_per_s": round(tuples / wall, 1),
            "egress_throughput_per_s": round(report.egress_throughput, 1),
            "p99_latency_ms": round(report.p99_latency * 1e3, 3),
            "mean_latency_ms": round(report.mean_latency * 1e3, 3),
            "busy_frac": round(report.worker_busy_frac, 3),
        })
    return rows


def _columnar_ab_configs():
    base = dict(
        workload="columnar_device", backend="process", batch_size=COL_BATCH,
        workers=2, device_batch=COL_BATCH, device_backend="numpy",
        max_inflight=32, reorder_size=1024,
    )
    return (dict(base, columnar=False), dict(base, columnar=True))


def _run_columnar_ab(seconds: float, workers: int):
    """The tentpole wire A/B: pickled units vs TAG_COLBLOCK spans through
    the same widen -> device -> device chain, interleaved over
    ``AB_ROUNDS`` so both sides sample the same host-speed regime.  Even
    interleaved, budget ~±20% ratio drift run-to-run on shared vCPUs."""
    pickle_cfg, col_cfg = _columnar_ab_configs()
    probe_n = 4000
    _, probe = _run_once(pickle_cfg, probe_n, workers)
    per_round = max(int(probe.throughput * seconds / AB_ROUNDS), probe_n)
    agg = {id(pickle_cfg): [0, 0.0, None], id(col_cfg): [0, 0.0, None]}
    for _ in range(AB_ROUNDS):
        for cfg in (pickle_cfg, col_cfg):
            pipe, report = _run_once(cfg, per_round, workers)
            slot = agg[id(cfg)]
            slot[0] += report.tuples_in
            slot[1] += report.wall_time
            slot[2] = (pipe, report)
    rows = []
    for cfg in (pickle_cfg, col_cfg):
        tuples, wall, (pipe, report) = agg[id(cfg)]
        rows.append({
            "workload": cfg["workload"],
            "backend": cfg["backend"],
            "batch_size": cfg["batch_size"],
            "stages": getattr(pipe, "num_stages", None),
            "workers": cfg["workers"],
            "columnar": cfg["columnar"],
            "device_batch": cfg["device_batch"],
            "device_backend": cfg["device_backend"],
            "interleaved_rounds": AB_ROUNDS,
            "tuples": tuples,
            "wall_s": round(wall, 3),
            "throughput_per_s": round(tuples / wall, 1),
            "egress_throughput_per_s": round(report.egress_throughput, 1),
            "p99_latency_ms": round(report.p99_latency * 1e3, 3),
            "mean_latency_ms": round(report.mean_latency * 1e3, 3),
            "busy_frac": round(report.worker_busy_frac, 3),
        })
    return rows


def _run_device_offload(seconds: float, workers: int):
    """Offload smoke row: one device stage on the pallas kernel (interpret
    mode) with columnar ingest — an absolute-throughput tracker for the
    dispatch path, not an A/B."""
    backend, kernel = _offload_backend()
    cfg = {
        "workload": "device_offload", "backend": "process",
        "batch_size": 64, "workers": 2, "columnar": True,
        "device_batch": 128, "device_backend": backend, "max_inflight": 32,
    }
    row = _run_config(cfg, seconds, workers)
    row["columnar"] = True
    row["device_backend"] = backend
    row["device_kernel"] = kernel
    return row


def run(seconds: float = 10.0, workers: int = 4, out: str = "BENCH_core.json",
        print_fn=print):
    rows = []
    for cfg in CONFIGS:
        row = _run_config(cfg, seconds, workers)
        rows.append(row)
        stages = "-" if row["stages"] is None else row["stages"]
        print_fn(
            f"{row['workload']:>14} {row['backend']:>7} "
            f"batch={row['batch_size']:<3} stages={stages:<2} "
            f"thru={row['throughput_per_s']:>10,.0f}/s "
            f"p99={row['p99_latency_ms']:.3f}ms busy={row['busy_frac']:.2f} "
            f"({row['tuples']} tuples / {row['wall_s']}s)"
        )
    row = _run_recovery(seconds, workers)
    rows.append(row)
    print_fn(
        f"{row['workload']:>14} {row['backend']:>7} "
        f"batch={row['batch_size']:<3} "
        f"goodput={row['throughput_per_s']:>10,.0f}/s "
        f"clean={row['clean_throughput_per_s']:>10,.0f}/s "
        f"recovery={row['recovery_latency_ms']:.1f}ms "
        f"restarts={row['restarts']}"
    )
    for row in _run_ab_configs(seconds, workers):
        rows.append(row)
        print_fn(
            f"{row['workload']:>14} {row['backend']:>7} "
            f"batch={row['batch_size']:<3} workers={row['workers']} "
            f"widths={row['stage_widths']} "
            f"thru={row['throughput_per_s']:>10,.0f}/s "
            f"({row['tuples']} tuples / {row['wall_s']}s interleaved)"
        )
    for row in _run_columnar_ab(seconds, workers):
        rows.append(row)
        wire = "colblock" if row["columnar"] else "pickle"
        print_fn(
            f"{row['workload']:>14} {row['backend']:>7} "
            f"batch={row['batch_size']:<3} wire={wire:<8} "
            f"thru={row['throughput_per_s']:>10,.0f}/s "
            f"busy={row['busy_frac']:.2f} "
            f"({row['tuples']} tuples / {row['wall_s']}s interleaved)"
        )
    row = _run_device_offload(seconds, workers)
    rows.append(row)
    print_fn(
        f"{row['workload']:>14} {row['backend']:>7} "
        f"batch={row['batch_size']:<3} "
        f"kernel={row['device_kernel']}({row['device_backend']}) "
        f"thru={row['throughput_per_s']:>10,.0f}/s "
        f"p99={row['p99_latency_ms']:.3f}ms "
        f"({row['tuples']} tuples / {row['wall_s']}s)"
    )
    row = _run_serving(seconds, workers)
    rows.append(row)
    print_fn(
        f"{row['workload']:>14} {row['backend']:>7} "
        f"sessions={row['sessions']} open-loop poisson "
        f"offered={row['offered_rate_per_s']:>8,.0f}/s "
        f"p50={row['p50_latency_ms']:.2f}ms p99={row['p99_latency_ms']:.2f}ms "
        f"p999={row['p999_latency_ms']:.2f}ms"
    )
    row = _run_elastic_serving(seconds, workers)
    rows.append(row)
    print_fn(
        f"{row['workload']:>14} {row['backend']:>7} "
        f"sessions={row['sessions']} open-loop bursty "
        f"grows={row['grows']} shrinks={row['shrinks']} "
        f"aborts={row['resize_aborts']} "
        f"p99={row['p99_latency_ms']:.2f}ms "
        f"static-p99={row['static_p99_latency_ms']:.2f}ms"
    )

    def thru(workload, backend, batch, staged=None):
        for r in rows:
            if (
                r["workload"] == workload
                and r["backend"] == backend
                and r["batch_size"] == batch
                and (
                    staged is None
                    or (r["stages"] != 1 if staged else r["stages"] == 1)
                )
            ):
                return r["throughput_per_s"]
        return 0.0

    def thru_workers(workload, auto):
        for r in rows:
            if r["workload"] == workload and (
                (r.get("workers") == "auto") == auto
            ):
                return r["throughput_per_s"]
        return 0.0

    ratios = {
        "process_vs_thread": round(
            thru("cpu_chain", "process", 1) /
            max(thru("cpu_chain", "thread", 1), 1e-9), 3,
        ),
        "thread_batch32_vs_batch1": round(
            thru("cpu_chain", "thread", 32) /
            max(thru("cpu_chain", "thread", 1), 1e-9), 3,
        ),
        # The PR-3 tentpole ratio: staged plan vs the PR-2 ingress-only plan
        # on the same workload.  The auto plan cuts SL|PS|SL into 2 stages
        # (the trailing stateless run folds into the keyed stage).
        "staged_vs_ingress_process": round(
            thru("keyed_hotspot", "process", 32, staged=True) /
            max(thru("keyed_hotspot", "process", 32, staged=False), 1e-9), 3,
        ),
        # The PR-4 tentpole ratio: cost-model worker allocation vs the flat
        # even split of the same budget (interleaved measurement).
        "auto_vs_flat_process": round(
            thru_workers("skewed_stages", auto=True) /
            max(thru_workers("skewed_stages", auto=False), 1e-9), 3,
        ),
        # The PR-7 robustness ratio: goodput under a mid-run keyed-worker
        # kill (checkpoint restore + replay included) vs the clean run.
        "recovery_goodput_vs_clean": round(
            thru("recovery", "process", 32) /
            max(next(
                (r["clean_throughput_per_s"] for r in rows
                 if r["workload"] == "recovery"), 0.0,
            ), 1e-9), 3,
        ),
        # The PR-10 tentpole ratio: TAG_COLBLOCK spans vs pickled units on
        # the same widen -> device -> device chain (interleaved; the
        # columnar side encodes blocks in the parallel upstream workers and
        # device stages ingest/relay them zero-copy).
        "columnar_vs_pickle_process": round(
            next((r["throughput_per_s"] for r in rows
                  if r["workload"] == "columnar_device" and r["columnar"]),
                 0.0) /
            max(next(
                (r["throughput_per_s"] for r in rows
                 if r["workload"] == "columnar_device"
                 and not r["columnar"]), 0.0,
            ), 1e-9), 3,
        ),
        # The PR-9 tentpole ratio: tail latency of the traffic-reactive
        # loop vs static widths on the same bursty trace (< 1 = reactive
        # resizes pay for themselves; the acceptance bar is <= 1.25).
        "elastic_serving_p99_vs_static": round(
            next((r["p99_latency_ms"] for r in rows
                  if r["workload"] == "elastic_serving"), 0.0) /
            max(next(
                (r["static_p99_latency_ms"] for r in rows
                 if r["workload"] == "elastic_serving"), 0.0,
            ), 1e-9), 3,
        ),
    }
    doc = {
        "meta": {
            "workloads": {
                "cpu_chain": f"fig8-style CPU-bound chain ({STAGES} stages, "
                             f"spin={SPIN})",
                "keyed_hotspot": f"SL(spin=30) -> PS(spin={HOT_SPIN}, keyed) "
                                 f"-> SL(spin=30) interior hot spot",
                "recovery": f"keyed_hotspot(spin_hot={RECOVERY_SPIN}) under "
                            "a seeded mid-run SIGKILL of the keyed stage's "
                            f"worker 0 (checkpoint_interval={RECOVERY_CKPT}; "
                            "goodput includes the restore+replay stall)",
                "skewed_stages": f"SL(spin={SKEW_HOT}, hot) -> "
                                 f"PS(spin={SKEW_COLD}, keyed cold): flat "
                                 "width 1 = even split of the default "
                                 "cores+1 budget over the 2 data-parallel "
                                 "stages; auto = cost-model division "
                                 f"(interleaved x{AB_ROUNDS})",
                "columnar_device": (
                    f"SL widen (scalar -> {COL_WIDTH}x i8 tuple) -> 2 device "
                    "affine stages (NumPy reference kernel), batch "
                    f"{COL_BATCH}: pickled units vs TAG_COLBLOCK spans on "
                    f"the same chain, interleaved x{AB_ROUNDS}; the ratio "
                    "still carries ~±20% host drift on shared vCPUs "
                    "(docs/columnar.md)"
                ),
                "device_offload": (
                    "widen -> 1 device stage with columnar ingest on the "
                    "jax/pallas kernel (interpret-mode pallas_call; NumPy "
                    "reference fallback recorded in device_backend when jax "
                    "is absent) — offload smoke row, not an A/B"
                ),
                "serving": f"{SERVING_SESSIONS} concurrent ordered sessions "
                           "multiplexed onto one runtime (SessionMux), "
                           "open-loop Poisson arrivals at "
                           f"{SERVING_UTIL:.0%} of probed capacity; "
                           "latency is coordinated-omission-free "
                           "(measured from scheduled arrival; probe "
                           "discards a 400-request warmup prefix)",
                "elastic_serving": f"{ELASTIC_SESSIONS} sessions, bursty "
                                   f"open-loop trace ({ELASTIC_DUTY:.0%} of "
                                   f"each period at {ELASTIC_BURST:g}x the "
                                   f"{ELASTIC_UTIL:.0%}-of-capacity mean) "
                                   "on the process backend: static widths "
                                   "vs the traffic-reactive loop (mux load "
                                   "signals -> TrafficMonitor grow/shrink "
                                   "of the sid-partitioned stage, p99 "
                                   "resize guard); reactive side reported",
            },
            "seconds_per_config": seconds,
            "cpu_count": os.cpu_count(),
            "python": platform.python_version(),
            "unix_time": int(time.time()),
        },
        "results": rows,
        "ratios": ratios,
    }
    with open(out, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print_fn(
        f"ratios: process/thread={ratios['process_vs_thread']}x  "
        f"batch32/batch1={ratios['thread_batch32_vs_batch1']}x  "
        f"staged/ingress={ratios['staged_vs_ingress_process']}x  "
        f"auto/flat={ratios['auto_vs_flat_process']}x  "
        f"columnar/pickle={ratios['columnar_vs_pickle_process']}x  "
        f"recovery/clean={ratios['recovery_goodput_vs_clean']}x  "
        f"elastic-p99/static={ratios['elastic_serving_p99_vs_static']}x  "
        f"-> {out}"
    )
    return doc


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="~1 s per config (CI plumbing check)")
    ap.add_argument("--seconds", type=float, default=None,
                    help="wall-clock window per config (default 10, smoke 1)")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--out", default="BENCH_core.json")
    args = ap.parse_args(argv)
    seconds = args.seconds if args.seconds is not None else (1.0 if args.smoke else 10.0)
    run(seconds=seconds, workers=args.workers, out=args.out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
