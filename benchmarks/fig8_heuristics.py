"""Fig. 8a/8b — scheduling heuristics (CT/LP/ET/QST) on TPCx-BB queries:
throughput and mean processing latency as cores scale (discrete-event sim
mirroring each query's operator cost/selectivity profile).
"""
from __future__ import annotations

from repro.core.simulate import SimConfig, simulate
from repro.streams.tpcxbb import sim_ops

from .common import fmt_row

N_TUPLES = 15_000
QUERIES = ("q1", "q2", "q3", "q4", "q15")
HEURISTICS = ("ct", "lp", "et", "qst")


def run(print_fn=print, workers=(2, 4, 8, 16), n_tuples=N_TUPLES):
    print_fn("fig,query,heuristic,workers,throughput_per_s,mean_latency_ms,p99_ms")
    for q in QUERIES:
        for h in HEURISTICS:
            for w in workers:
                ops = sim_ops(q)
                r = simulate(
                    ops,
                    n_tuples,
                    SimConfig(num_workers=w, heuristic=h),
                    key_sampler=lambda rng: rng.randrange(1 << 30),
                )
                print_fn(
                    fmt_row(
                        "fig8", q, h, w,
                        f"{r['throughput_per_s']:.0f}",
                        f"{r['mean_latency_us']/1e3:.3f}",
                        f"{r['p99_latency_us']/1e3:.3f}",
                    )
                )


if __name__ == "__main__":
    run()
