"""Fig. 8a/8b — scheduling heuristics (CT/LP/ET/QST) on TPCx-BB queries:
throughput and mean processing latency as cores scale (discrete-event sim
mirroring each query's operator cost/selectivity profile).

The DAG section additionally drives the *thread* runtime on the DAG forms of
the queries (keyed split -> parallel branches -> ordered merge), including the
``adaptive`` heuristic whose controller resizes per-node parallelism caps.

The backend section drives the *real* runtimes — thread vs process — on the
fig. 8 CPU-bound synthetic query so thread-vs-process scaling is directly
reported (the thread runtime is GIL-bound; the process backend is the point).
"""
from __future__ import annotations

from repro.core.simulate import SimConfig, simulate
from repro.streams.parametric import cpu_bound_chain
from repro.streams.tpcxbb import DAG_QUERIES, sim_ops

from .common import engine_run, fmt_row

N_TUPLES = 15_000
QUERIES = ("q1", "q2", "q3", "q4", "q15")
HEURISTICS = ("ct", "lp", "et", "qst")
DAG_HEURISTICS = ("ct", "lp", "et", "qst", "adaptive")
BACKENDS = ("thread", "process")


def run(print_fn=print, workers=(2, 4, 8, 16), n_tuples=N_TUPLES):
    print_fn("fig,query,heuristic,workers,throughput_per_s,mean_latency_ms,p99_ms")
    for q in QUERIES:
        for h in HEURISTICS:
            for w in workers:
                ops = sim_ops(q)
                r = simulate(
                    ops,
                    n_tuples,
                    SimConfig(num_workers=w, heuristic=h),
                    key_sampler=lambda rng: rng.randrange(1 << 30),
                )
                print_fn(
                    fmt_row(
                        "fig8", q, h, w,
                        f"{r['throughput_per_s']:.0f}",
                        f"{r['mean_latency_us']/1e3:.3f}",
                        f"{r['p99_latency_us']/1e3:.3f}",
                    )
                )
    run_dag(print_fn, n_tuples=min(n_tuples, 6000))
    run_backends(print_fn, n_tuples=min(n_tuples, 15_000))


def run_backends(print_fn=print, workers=(2, 4), n_tuples=15_000):
    """Thread vs process backends on the CPU-bound synthetic query (real
    parallelism; fig8 rows gain a backend column)."""
    for backend in BACKENDS:
        for w in workers:
            _, r = engine_run(
                cpu_bound_chain(stages=3, spin=100),
                range(n_tuples),
                num_workers=w,
                backend=backend,
            )
            print_fn(
                fmt_row(
                    "fig8backend", "cpu_synth", backend, w,
                    f"{r.throughput:.0f}",
                    f"{r.mean_latency*1e3:.3f}",
                    f"{r.p99_latency*1e3:.3f}",
                )
            )


def run_dag(print_fn=print, workers=(2, 4), n_tuples=6000):
    """DAG topologies on the thread runtime (real threads, ordered egress)."""
    for q, builder in DAG_QUERIES.items():
        for h in DAG_HEURISTICS:
            for w in workers:
                nodes, edges, src = builder(n=n_tuples)
                _, r = engine_run(
                    (nodes, edges), list(src), num_workers=w, heuristic=h
                )
                print_fn(
                    fmt_row(
                        "fig8dag", q, h, w,
                        f"{r.throughput:.0f}",
                        f"{r.mean_latency*1e3:.3f}",
                        f"{r.p99_latency*1e3:.3f}",
                    )
                )


if __name__ == "__main__":
    run()
