"""Fig. 11 — partitioning schemes on the TPCx-BB pipeline queries (CT
heuristic, 8 workers): peak throughput and latency, HYBRID vs PARTITIONED.
"""
from __future__ import annotations

from repro.core.simulate import SimConfig, simulate
from repro.streams.tpcxbb import sim_ops

from .common import fmt_row

QUERIES = ("q1", "q2", "q3", "q4", "q15")


def run(print_fn=print, n_tuples=15_000):
    print_fn("fig,query,scheme,throughput_per_s,mean_latency_ms")
    for q in QUERIES:
        for scheme in ("hybrid", "partitioned"):
            best_thru, best_lat = 0.0, 0.0
            for w in (2, 4, 8, 16):
                r = simulate(
                    sim_ops(q), n_tuples,
                    SimConfig(num_workers=w, worklist_scheme=scheme, heuristic="ct"),
                    key_sampler=lambda rng: rng.randrange(1 << 30),
                )
                if r["throughput_per_s"] > best_thru:
                    best_thru = r["throughput_per_s"]
                    best_lat = r["mean_latency_us"] / 1e3
            print_fn(fmt_row("fig11", q, scheme, f"{best_thru:.0f}", f"{best_lat:.3f}"))


if __name__ == "__main__":
    run()
