"""Fig. 11 — partitioning schemes on the TPCx-BB pipeline queries (CT
heuristic, 8 workers): peak throughput and latency, HYBRID vs PARTITIONED.

The DAG section runs the same scheme comparison on the DAG query forms
through the thread runtime: the worklist scheme applies to the partitioned
operators *inside* split/merge branches.
"""
from __future__ import annotations

from repro.core.simulate import SimConfig, simulate
from repro.streams.tpcxbb import DAG_QUERIES, sim_ops

from .common import engine_run, fmt_row

QUERIES = ("q1", "q2", "q3", "q4", "q15")


def run(print_fn=print, n_tuples=15_000):
    print_fn("fig,query,scheme,throughput_per_s,mean_latency_ms")
    for q in QUERIES:
        for scheme in ("hybrid", "partitioned"):
            best_thru, best_lat = 0.0, 0.0
            for w in (2, 4, 8, 16):
                r = simulate(
                    sim_ops(q), n_tuples,
                    SimConfig(num_workers=w, worklist_scheme=scheme, heuristic="ct"),
                    key_sampler=lambda rng: rng.randrange(1 << 30),
                )
                if r["throughput_per_s"] > best_thru:
                    best_thru = r["throughput_per_s"]
                    best_lat = r["mean_latency_us"] / 1e3
            print_fn(fmt_row("fig11", q, scheme, f"{best_thru:.0f}", f"{best_lat:.3f}"))
    run_dag(print_fn, n_tuples=min(n_tuples, 6000))
    run_backends(print_fn, n_tuples=min(n_tuples, 8000))


def run_backends(print_fn=print, n_tuples=8000):
    """Backend column on the real pipeline queries: peak throughput of the
    thread runtime vs the process backend (stateless-prefix parallelism)."""
    from repro.streams.tpcxbb import run_query

    for q in ("q1", "q4", "q15"):
        for backend in ("thread", "process"):
            best_thru, best_lat = 0.0, 0.0
            for w in (2, 4):
                _, r = run_query(q, n=n_tuples, backend=backend, num_workers=w)
                if r.throughput > best_thru:
                    best_thru = r.throughput
                    best_lat = r.mean_latency * 1e3
            print_fn(
                fmt_row("fig11backend", q, backend,
                        f"{best_thru:.0f}", f"{best_lat:.3f}")
            )


def run_dag(print_fn=print, n_tuples=6000):
    """Worklist schemes on DAG topologies (thread runtime, ordered egress)."""
    for q, builder in DAG_QUERIES.items():
        for scheme in ("hybrid", "partitioned"):
            best_thru, best_lat = 0.0, 0.0
            for w in (2, 4):
                nodes, edges, src = builder(n=n_tuples)
                _, r = engine_run(
                    (nodes, edges), list(src),
                    num_workers=w, heuristic="ct", worklist_scheme=scheme,
                )
                if r.throughput > best_thru:
                    best_thru = r.throughput
                    best_lat = r.mean_latency * 1e3
            print_fn(
                fmt_row("fig11dag", q, scheme, f"{best_thru:.0f}", f"{best_lat:.3f}")
            )


if __name__ == "__main__":
    run()
