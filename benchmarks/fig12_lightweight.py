"""Fig. 12 — light-weight stateless operator: speedup + per-tuple cost of the
NON-BLOCKING vs LOCK-BASED reordering schemes as workers scale.

Paper setup: stateless op, ~10us/tuple. Expectation: non-blocking scales
better; lock-based per-tuple cost (incl. blocked time) rises steeply.
"""
from __future__ import annotations

from repro.core.simulate import SimConfig, SimOp, simulate

from .common import fmt_row

N_TUPLES = 30_000
COST_US = 10.0


def run(print_fn=print):
    print_fn("fig,scheme,workers,speedup,avg_cost_us,blocked_ms")
    base = {}
    for scheme in ("non_blocking", "lock_based"):
        for w in (1, 2, 4, 8, 16):
            ops = [SimOp("light", "stateless", cost_us=COST_US)]
            r = simulate(
                ops,
                N_TUPLES,
                SimConfig(num_workers=w, reorder_scheme=scheme, heuristic="lp"),
            )
            if scheme == "non_blocking" and w == 1:
                base["t"] = r["makespan_us"]
            speedup = base["t"] / r["makespan_us"]
            avg_cost = sum(
                [r["makespan_us"] * w / N_TUPLES]
            )  # worker-time per tuple upper bound
            busy_cost = (
                r["worker_busy_frac"] * w * r["makespan_us"] / N_TUPLES
            )
            print_fn(
                fmt_row(
                    "fig12", scheme, w,
                    f"{speedup:.2f}", f"{busy_cost:.2f}",
                    f"{r['blocked_us']/1e3:.1f}",
                )
            )


if __name__ == "__main__":
    run()
