"""Benchmark harness: one module per paper table/figure. Prints CSV rows
(`fig,...` per figure; `kernels,name,variant,us_per_call,derived`).

  PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="smaller tuple counts")
    ap.add_argument("--only", default=None, help="comma-list: fig8,fig9,...")
    args = ap.parse_args()

    from . import (
        fig8_heuristics,
        fig9_load_balance,
        fig10_latency,
        fig11_pipeline_partitioning,
        fig12_lightweight,
        fig13_selectivity,
        fig14_pipeline_reorder,
        kernel_bench,
    )

    suites = {
        "fig8": lambda: fig8_heuristics.run(
            workers=(2, 4, 8, 16), n_tuples=4000 if args.quick else 15000
        ),
        "fig9": fig9_load_balance.run,
        "fig10": lambda: fig10_latency.run(n_tuples=2000 if args.quick else 8000),
        "fig11": lambda: fig11_pipeline_partitioning.run(
            n_tuples=4000 if args.quick else 15000
        ),
        "fig12": fig12_lightweight.run,
        "fig13": fig13_selectivity.run,
        "fig14": lambda: fig14_pipeline_reorder.run(
            n_tuples=4000 if args.quick else 15000
        ),
        "kernels": kernel_bench.run,
    }
    only = set(args.only.split(",")) if args.only else None
    for name, fn in suites.items():
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"# ==== {name} ====", flush=True)
        fn()
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
