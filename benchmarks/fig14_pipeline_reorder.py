"""Fig. 14 — reordering schemes on TPCx-BB queries (CT heuristic): peak
throughput, NON-BLOCKING vs LOCK-BASED.
"""
from __future__ import annotations

from repro.core.simulate import SimConfig, simulate
from repro.streams.tpcxbb import sim_ops

from .common import fmt_row

QUERIES = ("q1", "q2", "q3", "q4", "q15")


def run(print_fn=print, n_tuples=15_000):
    print_fn("fig,query,scheme,peak_throughput_per_s")
    for q in QUERIES:
        for scheme in ("non_blocking", "lock_based"):
            best = 0.0
            for w in (2, 4, 8, 16):
                r = simulate(
                    sim_ops(q), n_tuples,
                    SimConfig(num_workers=w, reorder_scheme=scheme, heuristic="ct"),
                    key_sampler=lambda rng: rng.randrange(1 << 30),
                )
                best = max(best, r["throughput_per_s"])
            print_fn(fmt_row("fig14", q, scheme, f"{best:.0f}"))


if __name__ == "__main__":
    run()
