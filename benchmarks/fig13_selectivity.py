"""Fig. 13 — high-selectivity pipeline: SL (sel=50, ~100us) -> PS. The
stateless op's outputs must enter the partitioned op's queues serially; the
NON-BLOCKING scheme avoids blocking workers on that serial section.
"""
from __future__ import annotations

from repro.core.simulate import SimConfig, SimOp, simulate

from .common import fmt_row

N_TUPLES = 1_000


def run(print_fn=print):
    print_fn("fig,scheme,workers,speedup,first_op_cost_us")
    base = None
    for scheme in ("non_blocking", "lock_based"):
        for w in (1, 2, 4, 8, 16):
            ops = [
                SimOp("fanout", "stateless", cost_us=100.0, selectivity=50.0),
                SimOp(
                    "ps", "partitioned", cost_us=2.0, num_partitions=128
                ),
            ]
            r = simulate(
                ops, N_TUPLES,
                SimConfig(num_workers=w, reorder_scheme=scheme, heuristic="ct"),
                key_sampler=lambda rng: rng.randrange(1 << 30),
            )
            if base is None:
                base = r["makespan_us"]
            speedup = base / r["makespan_us"]
            cost = r["worker_busy_frac"] * w * r["makespan_us"] / (N_TUPLES * 51)
            print_fn(fmt_row("fig13", scheme, w, f"{speedup:.2f}", f"{cost:.2f}"))


if __name__ == "__main__":
    run()
