"""Kernel micro-benchmarks: wall time of the Pallas kernels (interpret mode on
CPU — correctness-path timing; TPU perf comes from the §Roofline analysis)
plus their pure-jnp references, and derived bytes/flops per call.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from .common import fmt_row


def _time(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(print_fn=print):
    print_fn("bench,name,variant,us_per_call,derived")
    key = jax.random.PRNGKey(0)

    # reorder-commit: ring 256 x 128, batches of 32
    from repro.kernels.reorder import ops as reorder_ops

    S, W, K = 256, 128, 32
    state = reorder_ops.init_state(S, W)
    serials = jnp.arange(K, dtype=jnp.int32)
    payloads = jax.random.normal(key, (K, W))
    t_k = _time(lambda: reorder_ops.commit(state, serials, payloads, use_kernel=True))
    t_r = _time(lambda: reorder_ops.commit(state, serials, payloads, use_kernel=False))
    print_fn(fmt_row("kernels", "reorder_commit", "pallas", f"{t_k:.0f}", f"ring={S}x{W} K={K}"))
    print_fn(fmt_row("kernels", "reorder_commit", "jnp_ref", f"{t_r:.0f}", ""))

    # dispatch: 256 tuples -> 16 partitions cap 32, width 128
    from repro.kernels.dispatch import ops as dispatch_ops

    T, Pn, C, Wd = 256, 16, 32, 128
    ids = jax.random.randint(key, (T,), 0, Pn)
    pay = jax.random.normal(key, (T, Wd))
    t_k = _time(lambda: dispatch_ops.dispatch(ids, pay, Pn, C, use_kernel=True))
    t_r = _time(lambda: dispatch_ops.dispatch(ids, pay, Pn, C, use_kernel=False))
    print_fn(fmt_row("kernels", "dispatch", "pallas", f"{t_k:.0f}", f"T={T} P={Pn} C={C}"))
    print_fn(fmt_row("kernels", "dispatch", "jnp_ref", f"{t_r:.0f}", ""))

    # flash attention fwd: (1, 512, 4, 64)
    from repro.kernels.attention.flash import flash_attention
    from repro.kernels.attention.ref import attention_ref

    B, S2, H, Dh = 1, 512, 4, 64
    q = jax.random.normal(key, (B, S2, H, Dh), jnp.bfloat16)
    k = jax.random.normal(key, (B, S2, 2, Dh), jnp.bfloat16)
    v = jax.random.normal(key, (B, S2, 2, Dh), jnp.bfloat16)
    flops = 4 * B * H * S2 * S2 * Dh // 2  # causal
    t_k = _time(lambda: flash_attention(q, k, v, causal=True))
    t_r = _time(lambda: attention_ref(q, k, v, causal=True))
    print_fn(fmt_row("kernels", "flash_attention", "pallas", f"{t_k:.0f}", f"flops={flops:.2e}"))
    print_fn(fmt_row("kernels", "flash_attention", "jnp_ref", f"{t_r:.0f}", ""))

    # ssd: (1, 512, 4, 64) state 128
    from repro.kernels.ssd import ops as ssd_ops
    from repro.models.ssm import ssd_chunked

    B3, L, H3, P3, N3 = 1, 512, 4, 64, 128
    x = jax.random.normal(key, (B3, L, H3, P3))
    dt = jax.nn.softplus(jax.random.normal(key, (B3, L, H3)))
    A = -jnp.exp(jax.random.normal(key, (H3,)) * 0.3)
    Bm = jax.random.normal(key, (B3, L, N3)) * 0.3
    Cm = jax.random.normal(key, (B3, L, N3)) * 0.3
    t_k = _time(lambda: ssd_ops.ssd(x, dt, A, Bm, Cm, chunk=128))
    t_r = _time(lambda: ssd_chunked(x, dt, A, Bm, Cm, chunk=128))
    print_fn(fmt_row("kernels", "ssd_scan", "pallas", f"{t_k:.0f}", f"L={L} H={H3} N={N3}"))
    print_fn(fmt_row("kernels", "ssd_scan", "jnp_ref", f"{t_r:.0f}", ""))


if __name__ == "__main__":
    run()
