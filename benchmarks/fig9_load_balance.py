"""Fig. 9 — partitioned-parallelism under skew: HYBRID-QUEUE (100 partitions)
vs PARTITIONED-QUEUE (partitions = workers) with range-partitioned keys from
N(0, sigma); lower sigma = heavier skew. Metric: speedup over 1 worker.
"""
from __future__ import annotations

from repro.core.simulate import SimConfig, SimOp, simulate

from .common import fmt_row, gaussian_key_sampler

N_TUPLES = 20_000
COST_US = 100.0
WORKERS = 8


def run(print_fn=print):
    print_fn("fig,scheme,sigma,speedup")
    base = None
    for sigma in (2.0, 1.0, 0.5, 0.35, 0.25, 0.18):
        for scheme, parts in (("hybrid", 100), ("partitioned", WORKERS)):
            ops = [
                SimOp(
                    "partitioned_op", "partitioned",
                    cost_us=COST_US, num_partitions=parts,
                )
            ]
            r1 = simulate(
                ops, N_TUPLES,
                SimConfig(num_workers=1, worklist_scheme=scheme, heuristic="lp"),
                key_sampler=gaussian_key_sampler(sigma, key_space=parts),
            )
            ops2 = [
                SimOp(
                    "partitioned_op", "partitioned",
                    cost_us=COST_US, num_partitions=parts,
                )
            ]
            rw = simulate(
                ops2, N_TUPLES,
                SimConfig(num_workers=WORKERS, worklist_scheme=scheme, heuristic="lp"),
                key_sampler=gaussian_key_sampler(sigma, key_space=parts),
            )
            speedup = r1["makespan_us"] / rw["makespan_us"]
            print_fn(fmt_row("fig9", scheme, sigma, f"{speedup:.2f}"))


if __name__ == "__main__":
    run()
